//! Bit-exact IEEE 754 binary16 (FP16) emulation.
//!
//! The Hyperdrive datapath accumulates feature maps in FP16 (§VI: "We use
//! the half-precision floating point (FP16) number format for the FMs as a
//! conservative choice"). The functional simulator reproduces that
//! behaviour by rounding every intermediate accumulate to binary16 with
//! round-to-nearest-even, exactly like the chip's FP16 adder would.

/// An IEEE 754 binary16 value stored as its raw bit pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct F16(pub u16);

impl F16 {
    pub const ZERO: F16 = F16(0);

    /// Convert from f32 with round-to-nearest-even (the hardware default).
    pub fn from_f32(x: f32) -> F16 {
        F16(f32_to_f16_bits(x))
    }

    /// Widen to f32 (exact — every f16 is representable in f32).
    pub fn to_f32(self) -> f32 {
        f16_bits_to_f32(self.0)
    }

    /// FP16 add: widen, add in f32 (the adder's internal precision is at
    /// least the significand width, so a single operation is exact before
    /// the output rounding), round back to f16.
    pub fn add(self, rhs: F16) -> F16 {
        F16::from_f32(self.to_f32() + rhs.to_f32())
    }

    /// FP16 subtract (the "sign-input" path of the Tile-PU adder).
    pub fn sub(self, rhs: F16) -> F16 {
        F16::from_f32(self.to_f32() - rhs.to_f32())
    }

    /// FP16 multiply (the shared per-tile multiplier).
    pub fn mul(self, rhs: F16) -> F16 {
        F16::from_f32(self.to_f32() * rhs.to_f32())
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7c00) == 0x7c00 && (self.0 & 0x03ff) != 0
    }
}

/// f32 → binary16 bits, round-to-nearest-even, with denormal and
/// overflow-to-infinity handling.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN. Preserve a quiet NaN payload bit.
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }

    // Unbiased exponent, rebiased for f16 (bias 15).
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow → ±inf
    }
    if e <= 0 {
        // Subnormal or zero. shift = number of extra mantissa bits to drop.
        if e < -10 {
            return sign; // underflow to ±0
        }
        let m = mant | 0x0080_0000; // implicit leading 1
        let shift = (14 - e) as u32; // 14..24
        let half = 1u32 << (shift - 1);
        let rounded = m + (half - 1) + ((m >> shift) & 1);
        return sign | (rounded >> shift) as u16;
    }

    // Normal: drop 13 mantissa bits with RNE.
    let round_bit = 0x0000_1000u32;
    let m = mant + (round_bit - 1) + ((mant >> 13) & 1);
    if m & 0x0080_0000 != 0 {
        // Mantissa rounding overflowed into the exponent.
        let e2 = e + 1;
        if e2 >= 0x1f {
            return sign | 0x7c00;
        }
        return sign | ((e2 as u16) << 10);
    }
    sign | ((e as u16) << 10) | (m >> 13) as u16
}

/// binary16 bits → f32 (exact widening).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign // ±0
        } else {
            // Subnormal: value = mant·2⁻²⁴; normalize with k shifts so the
            // f32 biased exponent is 127 − 14 − k = 113 − k.
            let mut m = mant;
            let mut k = 0u32;
            while m & 0x0400 == 0 {
                m <<= 1;
                k += 1;
            }
            m &= 0x03ff;
            sign | ((113 - k) << 23) | (m << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13) // inf / NaN
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round an f32 to the nearest representable f16 value, staying in f32.
///
/// Fast path (§Perf log): values in the f16 *normal* range are rounded
/// by RNE bit-twiddling directly on the f32 representation (drop 13
/// mantissa bits), avoiding the two-way format conversion. Subnormals,
/// zeros, overflow and NaN take the exact slow path. Equivalence with
/// the reference conversion is property-tested below.
#[inline]
pub fn round_f16(x: f32) -> f32 {
    let bits = x.to_bits();
    let exp = (bits >> 23) & 0xff;
    // f16 normals: unbiased exponent −14..=15 → f32 biased 113..=142.
    if (113..=142).contains(&exp) {
        let rounded = bits.wrapping_add(0xfff + ((bits >> 13) & 1)) & !0x1fff;
        // Carry past 65504 overflows to +-inf (exp 143 after rounding).
        if (rounded >> 23) & 0xff == 143 {
            return f32::from_bits((bits & 0x8000_0000) | 0x7f80_0000);
        }
        return f32::from_bits(rounded);
    }
    f16_bits_to_f32(f32_to_f16_bits(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers_round_trip() {
        for i in -2048i32..=2048 {
            let x = i as f32;
            assert_eq!(round_f16(x), x, "f16 must represent |i| <= 2048");
        }
    }

    #[test]
    fn known_bit_patterns() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(0.5), 0x3800);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // f16::MAX
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00); // rounds to +inf
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
    }

    #[test]
    fn round_to_nearest_even_ties() {
        // 2049 is exactly between 2048 and 2050 → ties to even (2048).
        assert_eq!(round_f16(2049.0), 2048.0);
        // 2051 is between 2050 and 2052 → ties to even (2052).
        assert_eq!(round_f16(2051.0), 2052.0);
    }

    #[test]
    fn subnormals() {
        let min_sub = 5.960_464_5e-8; // 2^-24
        assert_eq!(f32_to_f16_bits(min_sub), 0x0001);
        assert_eq!(f16_bits_to_f32(0x0001), min_sub);
        // Below half the smallest subnormal → flush to zero.
        assert_eq!(f32_to_f16_bits(min_sub / 4.0), 0x0000);
    }

    #[test]
    fn nan_and_inf_propagate() {
        assert!(F16::from_f32(f32::NAN).is_nan());
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert_eq!(f16_bits_to_f32(0x7c00), f32::INFINITY);
    }

    #[test]
    fn fp16_accumulate_loses_precision_like_hardware() {
        // 2048 + 1 is not representable in f16 (ulp at 2048 is 2).
        let a = F16::from_f32(2048.0);
        let one = F16::from_f32(1.0);
        assert_eq!(a.add(one).to_f32(), 2048.0);
        // ...but 2048 + 2 is.
        let two = F16::from_f32(2.0);
        assert_eq!(a.add(two).to_f32(), 2050.0);
    }

    #[test]
    fn fast_round_matches_reference_conversion() {
        // The bit-twiddled fast path must agree with the exact two-way
        // conversion everywhere: random floats, boundaries, specials.
        let reference = |x: f32| f16_bits_to_f32(f32_to_f16_bits(x));
        let mut rng = crate::util::SplitMix64::new(0xf16);
        for _ in 0..200_000 {
            let bits = rng.next_u64() as u32;
            let x = f32::from_bits(bits);
            if x.is_nan() {
                assert!(round_f16(x).is_nan());
                continue;
            }
            let fast = round_f16(x);
            let slow = reference(x);
            assert_eq!(fast.to_bits(), slow.to_bits(), "x={x:e} ({bits:#010x})");
        }
        for x in [
            0.0f32, -0.0, 1.0, -1.0, 65504.0, 65519.9, 65520.0, 65536.0,
            -65520.0, 6.1e-5, 6.0e-5, 5.96e-8, 2.9e-8, 1e-40,
            f32::INFINITY, f32::NEG_INFINITY, f32::MAX, f32::MIN_POSITIVE,
        ] {
            assert_eq!(round_f16(x).to_bits(), reference(x).to_bits(), "x={x:e}");
        }
    }

    #[test]
    fn exhaustive_f16_to_f32_round_trip() {
        // Every finite f16 must survive f16 -> f32 -> f16 unchanged.
        for bits in 0u16..=0xffff {
            let h = F16(bits);
            if h.is_nan() {
                continue;
            }
            let back = F16::from_f32(h.to_f32());
            assert_eq!(back.0, bits, "bits {bits:#06x}");
        }
    }
}

//! Parser for the AOT artifact manifest (`artifacts/manifest.tsv`).
//!
//! The manifest is a line-oriented `key=value` format written by
//! `python/compile/aot.py` — deliberately trivial so the Rust side needs
//! no JSON dependency. Record kinds: `artifact`, `network`, `step`,
//! `blob`, `golden`, `blobfile`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One parsed record: the leading word plus its `key=value` fields.
#[derive(Debug, Clone)]
pub struct Record {
    pub kind: String,
    pub fields: HashMap<String, String>,
}

impl Record {
    pub fn get(&self, key: &str) -> Result<&str> {
        self.fields
            .get(key)
            .map(String::as_str)
            .with_context(|| format!("record `{}` missing field `{key}`", self.kind))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key)?
            .parse()
            .with_context(|| format!("field `{key}` is not a usize"))
    }

    pub fn get_isize(&self, key: &str) -> Result<isize> {
        self.get(key)?
            .parse()
            .with_context(|| format!("field `{key}` is not an isize"))
    }

    pub fn get_bool(&self, key: &str) -> Result<bool> {
        Ok(self.get_usize(key)? != 0)
    }
}

/// A parsed manifest plus the directory it lives in (for resolving files).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub records: Vec<Record>,
}

impl Manifest {
    /// Parse `dir/manifest.tsv`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Ok(Manifest {
            dir,
            records: parse(&text)?,
        })
    }

    /// All records of a given kind, in file order.
    pub fn of_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a Record> {
        self.records.iter().filter(move |r| r.kind == kind)
    }

    /// The unique record of a kind, or an error.
    pub fn unique<'a>(&'a self, kind: &str) -> Result<&'a Record> {
        let mut it = self.records.iter().filter(|r| r.kind == kind);
        let first = it
            .next()
            .with_context(|| format!("manifest has no `{kind}` record"))?;
        if it.next().is_some() {
            bail!("manifest has more than one `{kind}` record");
        }
        Ok(first)
    }

    /// Resolve a manifest-relative file name.
    pub fn file(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }
}

/// Record kinds the AOT manifest format defines (`python/compile/aot.py`
/// is the writer); anything else is a parse error, not silently-ignored
/// data.
pub const KNOWN_KINDS: [&str; 6] = ["artifact", "network", "step", "blob", "golden", "blobfile"];

/// Parse manifest text into records. Blank lines and `#` comments skipped.
pub fn parse(text: &str) -> Result<Vec<Record>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let kind = parts
            .next()
            .with_context(|| format!("line {}: empty record", lineno + 1))?
            .to_string();
        if !KNOWN_KINDS.contains(&kind.as_str()) {
            bail!("line {}: unknown record kind `{kind}`", lineno + 1);
        }
        let mut fields = HashMap::new();
        for part in parts {
            let (k, v) = part
                .split_once('=')
                .with_context(|| format!("line {}: token `{part}` is not key=value", lineno + 1))?;
            if fields.insert(k.to_string(), v.to_string()).is_some() {
                bail!("line {}: duplicate key `{k}`", lineno + 1);
            }
        }
        out.push(Record { kind, fields });
    }
    Ok(out)
}

/// Read a raw little-endian f32 blob file.
pub fn read_f32_blob(path: impl AsRef<Path>) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    if bytes.len() % 4 != 0 {
        bail!("blob length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
artifact name=conv_a kind=conv k=3 stride=1 n_in=16 n_out=16 h=32 w=32 bypass=0 relu=1 dtype=f32 file=a.hlo.txt

step idx=0 name=s1b0c1 artifact=conv_a src=-1 bypass=-2
blob step=s1b0c1 field=w off=0 len=2304
";

    #[test]
    fn parses_kinds_and_fields() {
        let recs = parse(SAMPLE).unwrap();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].kind, "artifact");
        assert_eq!(recs[0].get("name").unwrap(), "conv_a");
        assert_eq!(recs[0].get_usize("k").unwrap(), 3);
        assert!(!recs[0].get_bool("bypass").unwrap());
        assert_eq!(recs[1].get_isize("src").unwrap(), -1);
        assert_eq!(recs[1].get_isize("bypass").unwrap(), -2);
        assert_eq!(recs[2].get_usize("len").unwrap(), 2304);
    }

    #[test]
    fn missing_field_is_contextual_error() {
        let recs = parse("artifact name=x").unwrap();
        let err = recs[0].get("kind").unwrap_err().to_string();
        assert!(err.contains("missing field `kind`"), "{err}");
    }

    #[test]
    fn rejects_malformed_tokens() {
        assert!(parse("artifact name").is_err());
        assert!(parse("artifact a=1 a=2").is_err());
    }

    #[test]
    fn unknown_record_kind_is_rejected_with_line_number() {
        let err = parse("artifact name=x\nwibble a=1").unwrap_err().to_string();
        assert!(err.contains("unknown record kind `wibble`"), "{err}");
        assert!(err.contains("line 2"), "{err}");
        // All kinds the writer emits parse.
        for kind in KNOWN_KINDS {
            assert!(parse(&format!("{kind} a=1")).is_ok(), "{kind}");
        }
    }

    #[test]
    fn bad_numeric_fields_are_contextual_errors() {
        let recs = parse("blob step=s field=w off=abc len=-4").unwrap();
        let err = recs[0].get_usize("off").unwrap_err().to_string();
        assert!(err.contains("`off` is not a usize"), "{err}");
        // A negative value is not a usize either, but is a valid isize.
        assert!(recs[0].get_usize("len").is_err());
        assert_eq!(recs[0].get_isize("len").unwrap(), -4);
        let err = recs[0].get_isize("off").unwrap_err().to_string();
        assert!(err.contains("`off` is not an isize"), "{err}");
        // get_bool goes through get_usize.
        assert!(recs[0].get_bool("off").is_err());
    }

    #[test]
    fn blob_with_bad_length_is_rejected() {
        let dir = std::env::temp_dir().join("hyperdrive_manifest_badlen");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("blob.bin");
        std::fs::write(&p, [0u8; 7]).unwrap();
        let err = read_f32_blob(&p).unwrap_err().to_string();
        assert!(err.contains("not a multiple of 4"), "{err}");
    }

    #[test]
    fn f32_blob_round_trip() {
        let dir = std::env::temp_dir().join("hyperdrive_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("blob.bin");
        let vals = [1.0f32, -2.5, 3.25e-3];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        assert_eq!(read_f32_blob(&p).unwrap(), vals);
    }
}

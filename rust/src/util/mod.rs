//! Small in-repo substrates: bit-exact FP16 emulation, a deterministic
//! PRNG, and the artifact-manifest parser.
//!
//! These exist because the offline vendored crate set has no `half`,
//! `rand` or `serde_json`; each is small, fully tested, and behaviourally
//! sufficient for the reproduction (see DESIGN.md §Substitutions).

pub mod f16;
pub mod manifest;
pub mod rng;

pub use f16::F16;
pub use rng::SplitMix64;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Format a bit count the way the paper's tables do (e.g. `6.4M`, `459k`).
pub fn fmt_bits(bits: u64) -> String {
    if bits >= 1_000_000_000 {
        format!("{:.1}G", bits as f64 / 1e9)
    } else if bits >= 1_000_000 {
        format!("{:.1}M", bits as f64 / 1e6)
    } else if bits >= 1_000 {
        format!("{:.1}k", bits as f64 / 1e3)
    } else {
        format!("{bits}")
    }
}

/// Format an operation count (`7.10G`, `2.94M`, ...).
pub fn fmt_ops(ops: u64) -> String {
    fmt_bits(ops)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(224, 7), 32);
        assert_eq!(ceil_div(225, 7), 33);
        assert_eq!(ceil_div(1, 7), 1);
        assert_eq!(ceil_div(0, 7), 0);
    }

    #[test]
    fn bit_formatting_matches_paper_style() {
        assert_eq!(fmt_bits(6_400_000), "6.4M");
        assert_eq!(fmt_bits(459_000), "459.0k");
        assert_eq!(fmt_bits(2_500_000_000), "2.5G");
        assert_eq!(fmt_bits(12), "12");
    }
}

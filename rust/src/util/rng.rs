//! Deterministic PRNG (SplitMix64) — used by the test kit, workload
//! generators and synthetic-weight paths. No external `rand` dependency.

/// SplitMix64: tiny, fast, passes BigCrush for this use; deterministic
/// across platforms, which matters for golden-value tests.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in [-1, 1).
    pub fn next_sym(&mut self) -> f32 {
        2.0 * self.next_f32() - 1.0
    }

    /// Approximately standard-normal f32 (sum of 12 uniforms − 6;
    /// Irwin–Hall — plenty for synthetic FMs/weights).
    pub fn next_gauss(&mut self) -> f32 {
        let mut s = 0.0f32;
        for _ in 0..12 {
            s += self.next_f32();
        }
        s - 6.0
    }

    /// Uniform usize in [0, n).
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Random sign: ±1.0 with equal probability.
    pub fn next_sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn known_first_value() {
        // Golden value — guards against silent algorithm changes that
        // would invalidate every golden test downstream.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xe220_a839_7b1d_cdaf);
    }

    #[test]
    fn uniform_range_and_rough_mean() {
        let mut r = SplitMix64::new(7);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn gauss_rough_moments() {
        let mut r = SplitMix64::new(9);
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        let n = 20_000;
        for _ in 0..n {
            let x = r.next_gauss() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn signs_are_balanced() {
        let mut r = SplitMix64::new(3);
        let pos = (0..10_000).filter(|_| r.next_sign() > 0.0).count();
        assert!((4_700..5_300).contains(&pos), "pos {pos}");
    }
}

//! Per-tile dirty tracking for temporal (frame-to-frame) reuse.
//!
//! A [`DirtyMap`] covers one tensor with a grid of square `tile`-pixel
//! tiles and remembers which tiles changed since the previous frame.
//! Dirtiness enters at the input ([`DirtyMap::from_diff`]: any pixel of
//! any channel deviating beyond an epsilon marks its tile) and is
//! pushed through the network layer by layer:
//!
//! * [`DirtyMap::propagate`] dilates through a conv layer's receptive
//!   field — an output tile is dirty iff the input rows/cols its k×k
//!   taps can read (at the layer's stride, same-padding clamped to the
//!   FM) intersect a dirty input tile. Taps form contiguous per-pixel
//!   ranges and tiles are contiguous pixel runs, so the rect-overlap
//!   test is *exactly* receptive-field reachability, not merely a
//!   superset (property-tested against brute force in
//!   `tests/video_stream.rs`);
//! * [`DirtyMap::upsample`] maps through the mesh's free 2× nearest
//!   upsampling (output pixel `(y, x)` reads `(y/2, x/2)`);
//! * [`DirtyMap::union`] merges the extra dirtiness of bypass and
//!   concat sources (both are elementwise in space, so their maps OR
//!   straight into the consumer's).
//!
//! Because a clean output tile's whole receptive field is clean, and
//! the cached clean values *are* what the kernel would recompute from
//! those unchanged inputs, splicing cached tiles and running the
//! unmodified kernel only on dirty tiles reproduces a full recompute
//! bit for bit — at FP16 exactly as at f32 (see DESIGN.md §Streaming
//! video).

use crate::network::ConvLayer;
use crate::simulator::fm::FeatureMap;

/// Which tiles of one `h×w` tensor changed since the previous frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DirtyMap {
    /// Pixel dims of the tensor this map covers.
    pub h: usize,
    pub w: usize,
    /// Square tile edge in pixels (edge tiles may be smaller).
    pub tile: usize,
    th: usize,
    tw: usize,
    bits: Vec<bool>,
}

impl DirtyMap {
    /// All-clean map over an `h×w` tensor.
    pub fn clean(h: usize, w: usize, tile: usize) -> DirtyMap {
        assert!(tile > 0, "tile size must be positive");
        assert!(h > 0 && w > 0, "empty tensor");
        let (th, tw) = (h.div_ceil(tile), w.div_ceil(tile));
        DirtyMap {
            h,
            w,
            tile,
            th,
            tw,
            bits: vec![false; th * tw],
        }
    }

    /// All-dirty map (what a keyframe / first frame uses).
    pub fn all_dirty(h: usize, w: usize, tile: usize) -> DirtyMap {
        let mut m = DirtyMap::clean(h, w, tile);
        m.bits.iter_mut().for_each(|b| *b = true);
        m
    }

    /// Diff two frames: a tile is dirty iff any pixel of any channel
    /// deviates by more than `eps` (NaN counts as deviating).
    pub fn from_diff(prev: &FeatureMap, next: &FeatureMap, tile: usize, eps: f32) -> DirtyMap {
        assert_eq!((prev.c, prev.h, prev.w), (next.c, next.h, next.w));
        let mut m = DirtyMap::clean(prev.h, prev.w, tile);
        let plane = prev.h * prev.w;
        for c in 0..prev.c {
            for y in 0..prev.h {
                let row = c * plane + y * prev.w;
                for x in 0..prev.w {
                    let d = (prev.data[row + x] - next.data[row + x]).abs();
                    // `!(d <= eps)` so a NaN delta also dirties.
                    if !(d <= eps) {
                        m.bits[(y / tile) * m.tw + x / tile] = true;
                    }
                }
            }
        }
        m
    }

    /// Tile-grid shape `(rows, cols)`.
    pub fn grid(&self) -> (usize, usize) {
        (self.th, self.tw)
    }

    pub fn is_dirty_tile(&self, ty: usize, tx: usize) -> bool {
        self.bits[ty * self.tw + tx]
    }

    pub fn mark_tile(&mut self, ty: usize, tx: usize) {
        self.bits[ty * self.tw + tx] = true;
    }

    /// Mark every tile intersecting the pixel rect `[y0, y1) × [x0, x1)`.
    pub fn mark_rect(&mut self, y0: usize, y1: usize, x0: usize, x1: usize) {
        let (y1, x1) = (y1.min(self.h), x1.min(self.w));
        if y0 >= y1 || x0 >= x1 {
            return;
        }
        for ty in y0 / self.tile..=(y1 - 1) / self.tile {
            for tx in x0 / self.tile..=(x1 - 1) / self.tile {
                self.bits[ty * self.tw + tx] = true;
            }
        }
    }

    /// Pixel rect `[y0, y1) × [x0, x1)` of tile `(ty, tx)`, clamped.
    pub fn tile_rect(&self, ty: usize, tx: usize) -> (usize, usize, usize, usize) {
        (
            ty * self.tile,
            ((ty + 1) * self.tile).min(self.h),
            tx * self.tile,
            ((tx + 1) * self.tile).min(self.w),
        )
    }

    pub fn any_dirty(&self) -> bool {
        self.bits.iter().any(|&b| b)
    }

    /// Fraction of *pixels* lying in dirty tiles (edge tiles weigh
    /// their true pixel count, so this is exact, not tile-count based).
    pub fn dirty_pixel_fraction(&self) -> f64 {
        self.dirty_pixels() as f64 / (self.h * self.w) as f64
    }

    /// Number of pixels lying in dirty tiles.
    pub fn dirty_pixels(&self) -> u64 {
        let mut n = 0u64;
        for ty in 0..self.th {
            for tx in 0..self.tw {
                if self.bits[ty * self.tw + tx] {
                    let (y0, y1, x0, x1) = self.tile_rect(ty, tx);
                    n += ((y1 - y0) * (x1 - x0)) as u64;
                }
            }
        }
        n
    }

    /// Dirty region as disjoint rects, horizontally-adjacent dirty
    /// tiles merged into row runs (fewer kernel invocations).
    pub fn rects(&self) -> Vec<(usize, usize, usize, usize)> {
        let mut out = Vec::new();
        for ty in 0..self.th {
            let mut tx = 0;
            while tx < self.tw {
                if !self.bits[ty * self.tw + tx] {
                    tx += 1;
                    continue;
                }
                let run0 = tx;
                while tx < self.tw && self.bits[ty * self.tw + tx] {
                    tx += 1;
                }
                let (y0, y1, x0, _) = self.tile_rect(ty, run0);
                let (_, _, _, x1) = self.tile_rect(ty, tx - 1);
                out.push((y0, y1, x0, x1));
            }
        }
        out
    }

    /// True iff any tile overlapping the pixel rect
    /// `[y0, y1] × [x0, x1]` (**inclusive** bounds) is dirty.
    fn rect_dirty_incl(&self, y0: usize, y1: usize, x0: usize, x1: usize) -> bool {
        for ty in y0 / self.tile..=y1 / self.tile {
            for tx in x0 / self.tile..=x1 / self.tile {
                if self.bits[ty * self.tw + tx] {
                    return true;
                }
            }
        }
        false
    }

    /// Dilate through one conv layer: the returned map covers the
    /// layer's `h_out × w_out` output; an output tile is dirty iff the
    /// input rows/cols its pixels' k×k taps can read (same padding,
    /// clamped) intersect a dirty input tile. Exact receptive-field
    /// reachability — taps form contiguous ranges, so the union over a
    /// tile of output pixels is one contiguous rect.
    pub fn propagate(&self, l: &ConvLayer) -> DirtyMap {
        assert_eq!((self.h, self.w), (l.h, l.w), "map covers the layer input");
        let (ho, wo) = (l.h_out(), l.w_out());
        let dlo = -((l.k / 2) as isize);
        let dhi = (l.k - 1) as isize + dlo;
        let span = |o0: usize, o1: usize, dim: usize| -> (usize, usize) {
            let lo = ((o0 * l.stride) as isize + dlo).max(0) as usize;
            let hi = (((o1 - 1) * l.stride) as isize + dhi).min(dim as isize - 1) as usize;
            // The stride-0 tap (d = 0) is always in `dlo..=dhi` and
            // in-bounds, so `lo <= hi` holds for every valid tile.
            (lo, hi)
        };
        let mut out = DirtyMap::clean(ho, wo, self.tile);
        for ty in 0..out.th {
            for tx in 0..out.tw {
                let (oy0, oy1, ox0, ox1) = out.tile_rect(ty, tx);
                let (y0, y1) = span(oy0, oy1, l.h);
                let (x0, x1) = span(ox0, ox1, l.w);
                if self.rect_dirty_incl(y0, y1, x0, x1) {
                    out.bits[ty * out.tw + tx] = true;
                }
            }
        }
        out
    }

    /// Dilate through the free 2× nearest upsample: output pixel
    /// `(y, x)` reads input `(y/2, x/2)`.
    pub fn upsample(&self) -> DirtyMap {
        let mut out = DirtyMap::clean(self.h * 2, self.w * 2, self.tile);
        for ty in 0..out.th {
            for tx in 0..out.tw {
                let (oy0, oy1, ox0, ox1) = out.tile_rect(ty, tx);
                if self.rect_dirty_incl(oy0 / 2, (oy1 - 1) / 2, ox0 / 2, (ox1 - 1) / 2) {
                    out.bits[ty * out.tw + tx] = true;
                }
            }
        }
        out
    }

    /// OR another map of the same geometry into this one (bypass /
    /// concat sources are spatially elementwise).
    pub fn union(&mut self, other: &DirtyMap) {
        assert_eq!(
            (self.h, self.w, self.tile),
            (other.h, other.w, other.tile),
            "union needs identical geometry"
        );
        for (a, &b) in self.bits.iter_mut().zip(&other.bits) {
            *a |= b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diff_marks_only_changed_tiles() {
        let a = FeatureMap::zeros(2, 8, 8);
        let mut b = a.clone();
        b.set(1, 5, 2, 0.5);
        let m = DirtyMap::from_diff(&a, &b, 4, 0.0);
        assert!(m.is_dirty_tile(1, 0));
        assert_eq!(m.dirty_pixels(), 16);
        assert!(!m.is_dirty_tile(0, 0));
        assert!(!m.is_dirty_tile(0, 1));
        assert!(!m.is_dirty_tile(1, 1));
        // Below-epsilon wiggle stays clean; NaN always dirties.
        let mut c = a.clone();
        c.set(0, 0, 0, 1e-4);
        assert!(!DirtyMap::from_diff(&a, &c, 4, 1e-3).any_dirty());
        c.set(0, 0, 0, f32::NAN);
        assert!(DirtyMap::from_diff(&a, &c, 4, 1e-3).is_dirty_tile(0, 0));
    }

    #[test]
    fn propagate_dilates_by_receptive_field() {
        // 8×8, tile 2: dirty tile (1,1) covers pixels 2..4 × 2..4. A
        // 3×3/stride-1 layer reaches outputs 1..5 × 1..5, i.e. tiles
        // (0..3, 0..3); tile (3, 3) stays clean.
        let l = ConvLayer::new("t", 1, 1, 8, 8, 3, 1);
        let mut m = DirtyMap::clean(8, 8, 2);
        m.mark_tile(1, 1);
        let out = m.propagate(&l);
        for ty in 0..4 {
            for tx in 0..4 {
                assert_eq!(
                    out.is_dirty_tile(ty, tx),
                    ty < 3 && tx < 3,
                    "tile ({ty},{tx})"
                );
            }
        }
        // 1×1/stride-1 propagates identity.
        let l1 = ConvLayer::new("i", 1, 1, 8, 8, 1, 1);
        assert_eq!(m.propagate(&l1), m);
    }

    #[test]
    fn stride_two_halves_the_grid() {
        let l = ConvLayer::new("s", 1, 1, 8, 8, 3, 2);
        let mut m = DirtyMap::clean(8, 8, 2);
        m.mark_tile(3, 3); // pixels 6..8 × 6..8
        let out = m.propagate(&l);
        assert_eq!(out.grid(), (2, 2));
        // Output pixels 2..4 read input rows 3..8 ⊇ dirty; outputs 0..2
        // read rows −1..4, clean.
        assert!(out.is_dirty_tile(1, 1));
        assert!(!out.is_dirty_tile(0, 0));
        assert!(!out.is_dirty_tile(0, 1));
        assert!(!out.is_dirty_tile(1, 0));
    }

    #[test]
    fn upsample_doubles_geometry() {
        let mut m = DirtyMap::clean(4, 4, 2);
        m.mark_tile(0, 1); // pixels 0..2 × 2..4 → upsampled 0..4 × 4..8
        let up = m.upsample();
        assert_eq!((up.h, up.w), (8, 8));
        for ty in 0..4 {
            for tx in 0..4 {
                assert_eq!(
                    up.is_dirty_tile(ty, tx),
                    ty < 2 && tx >= 2,
                    "tile ({ty},{tx})"
                );
            }
        }
    }

    #[test]
    fn rects_merge_row_runs() {
        let mut m = DirtyMap::clean(6, 9, 3);
        m.mark_tile(0, 0);
        m.mark_tile(0, 1);
        m.mark_tile(1, 2);
        assert_eq!(m.rects(), vec![(0, 3, 0, 6), (3, 6, 6, 9)]);
        assert_eq!(m.dirty_pixels(), 27);
    }

    #[test]
    fn union_ors_bits() {
        let mut a = DirtyMap::clean(4, 4, 2);
        let mut b = DirtyMap::clean(4, 4, 2);
        a.mark_tile(0, 0);
        b.mark_tile(1, 1);
        a.union(&b);
        assert!(a.is_dirty_tile(0, 0) && a.is_dirty_tile(1, 1));
        assert_eq!(a.dirty_pixels(), 8);
    }
}

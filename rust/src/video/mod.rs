//! # Streaming-video subsystem: temporal reuse + multi-model placement
//!
//! Hyperdrive's stationary-FM design keeps activations resident on
//! chip; this subsystem extends the idea across *time*. In a smart-
//! camera stream consecutive frames mostly agree, so a session that
//! keeps the previous frame's per-layer activations resident only has
//! to recompute what changed:
//!
//! * [`dirty`] — per-tile change tracking ([`DirtyMap`]): diff-based
//!   marking, exact receptive-field dilation through conv layers,
//!   2× upsample mapping, bypass/concat unions.
//! * [`session`] — [`FrameSession`]: change-based execution on either
//!   simulator backend, bit-exact versus full per-frame recompute by
//!   construction, with per-frame saved-MAC/traffic accounting
//!   ([`FrameStats`]).
//! * [`synth`] — [`SynthVideo`]: seeded synthetic frame deltas (static
//!   background + moving patches) for benches, the loadgen `--video`
//!   replay mode and the bit-exactness sweeps.
//! * [`placement`] — [`MeshPlacement`]: carve one chip pool into
//!   rectangular sub-meshes so several resident models serve
//!   concurrently ([`crate::engine::ModelConfig::sub_mesh`]).

pub mod dirty;
pub mod placement;
pub mod session;
pub mod synth;

pub use dirty::DirtyMap;
pub use placement::{MeshPlacement, PlacementError, SubMesh};
pub use session::{FrameSession, FrameStats, VideoConfig, VideoError};
pub use synth::SynthVideo;

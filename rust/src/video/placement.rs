//! Multi-model mesh placement: carve one simulated chip pool into
//! rectangular sub-meshes, one per resident model, so the whole
//! registry zoo serves concurrently from a single device.
//!
//! The pool is a `rows × cols` grid of identical chips. Each model asks
//! for at least `min_chips` chips; the allocator picks the smallest
//! rectangle holding that many (squarest first among equals, for short
//! exchange paths) and places it first-fit, scanning anchors row-major
//! over the free grid — fully deterministic, so a placement plan can be
//! reproduced from the model list alone. Overflow is a typed
//! [`PlacementError`], not a panic: the serving layer turns it into an
//! admission decision.

use std::collections::BTreeMap;
use std::fmt;

/// One model's rectangular slice of the chip pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubMesh {
    /// Anchor (top-left chip) inside the pool.
    pub row0: usize,
    pub col0: usize,
    /// Sub-mesh shape — what the model's engine runs on.
    pub rows: usize,
    pub cols: usize,
}

impl SubMesh {
    pub fn chips(&self) -> usize {
        self.rows * self.cols
    }

    fn overlaps(&self, o: &SubMesh) -> bool {
        self.row0 < o.row0 + o.rows
            && o.row0 < self.row0 + self.rows
            && self.col0 < o.col0 + o.cols
            && o.col0 < self.col0 + self.cols
    }
}

impl fmt::Display for SubMesh {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}x{}@({},{})",
            self.rows, self.cols, self.row0, self.col0
        )
    }
}

/// Why a model could not be placed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlacementError {
    /// No free rectangle of the needed size exists (fragmentation or a
    /// genuinely full pool). `free` is how many chips remain unowned.
    PoolExhausted {
        model: String,
        needed: usize,
        free: usize,
    },
    /// `min_chips` exceeds the whole pool — can never fit.
    LargerThanPool {
        model: String,
        needed: usize,
        pool: usize,
    },
    /// A model of this name already holds a sub-mesh.
    AlreadyPlaced { model: String },
}

impl fmt::Display for PlacementError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlacementError::PoolExhausted {
                model,
                needed,
                free,
            } => write!(
                f,
                "no free rectangle for `{model}` (needs {needed} chips, {free} free)"
            ),
            PlacementError::LargerThanPool {
                model,
                needed,
                pool,
            } => write!(
                f,
                "`{model}` needs {needed} chips but the pool only has {pool}"
            ),
            PlacementError::AlreadyPlaced { model } => {
                write!(f, "`{model}` already holds a sub-mesh")
            }
        }
    }
}

impl std::error::Error for PlacementError {}

/// First-fit rectangular allocator over one chip pool.
pub struct MeshPlacement {
    rows: usize,
    cols: usize,
    /// model → placed sub-mesh; BTreeMap so iteration (and the
    /// rendered diagram) is deterministic.
    placed: BTreeMap<String, SubMesh>,
}

impl MeshPlacement {
    pub fn new(rows: usize, cols: usize) -> MeshPlacement {
        assert!(rows > 0 && cols > 0, "empty chip pool");
        MeshPlacement {
            rows,
            cols,
            placed: BTreeMap::new(),
        }
    }

    pub fn pool_shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn free_chips(&self) -> usize {
        self.rows * self.cols - self.placed.values().map(SubMesh::chips).sum::<usize>()
    }

    pub fn get(&self, model: &str) -> Option<SubMesh> {
        self.placed.get(model).copied()
    }

    pub fn placements(&self) -> impl Iterator<Item = (&str, SubMesh)> {
        self.placed.iter().map(|(m, s)| (m.as_str(), *s))
    }

    /// Candidate shapes for `min_chips`, smallest area first, squarest
    /// first among equal areas, and deterministic overall.
    fn shapes(&self, min_chips: usize) -> Vec<(usize, usize)> {
        let mut shapes = Vec::new();
        for r in 1..=self.rows {
            let c = min_chips.div_ceil(r);
            if c <= self.cols {
                shapes.push((r, c));
            }
        }
        shapes.sort_by_key(|&(r, c)| (r * c, r.abs_diff(c), r));
        shapes.dedup();
        shapes
    }

    /// Place `model`, claiming the first free rectangle of the best
    /// shape holding at least `min_chips` chips.
    pub fn place(&mut self, model: &str, min_chips: usize) -> Result<SubMesh, PlacementError> {
        let min_chips = min_chips.max(1);
        if self.placed.contains_key(model) {
            return Err(PlacementError::AlreadyPlaced {
                model: model.to_string(),
            });
        }
        if min_chips > self.rows * self.cols {
            return Err(PlacementError::LargerThanPool {
                model: model.to_string(),
                needed: min_chips,
                pool: self.rows * self.cols,
            });
        }
        for (r, c) in self.shapes(min_chips) {
            for row0 in 0..=self.rows - r {
                for col0 in 0..=self.cols - c {
                    let cand = SubMesh {
                        row0,
                        col0,
                        rows: r,
                        cols: c,
                    };
                    if self.placed.values().all(|s| !s.overlaps(&cand)) {
                        self.placed.insert(model.to_string(), cand);
                        return Ok(cand);
                    }
                }
            }
        }
        Err(PlacementError::PoolExhausted {
            model: model.to_string(),
            needed: min_chips,
            free: self.free_chips(),
        })
    }

    /// Release a model's sub-mesh (model unload). Returns the freed
    /// slice, `None` if the model held nothing.
    pub fn release(&mut self, model: &str) -> Option<SubMesh> {
        self.placed.remove(model)
    }

    /// ASCII ownership diagram: one letter per chip, `.` for free, a
    /// legend line per model. The DESIGN.md placement diagram is this
    /// output verbatim.
    pub fn render(&self) -> String {
        let mut grid = vec![b'.'; self.rows * self.cols];
        let mut legend = String::new();
        for (i, (model, s)) in self.placed.iter().enumerate() {
            let ch = b'A' + (i % 26) as u8;
            for r in s.row0..s.row0 + s.rows {
                for c in s.col0..s.col0 + s.cols {
                    grid[r * self.cols + c] = ch;
                }
            }
            legend.push_str(&format!("  {} = {model} ({s})\n", ch as char));
        }
        let mut out = String::new();
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.push(grid[r * self.cols + c] as char);
            }
            out.push('\n');
        }
        out.push_str(&legend);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_fit_packs_disjoint_rectangles() {
        let mut p = MeshPlacement::new(4, 4);
        let a = p.place("resnet", 4).unwrap();
        let b = p.place("yolo", 4).unwrap();
        assert_eq!((a.rows * a.cols, b.rows * b.cols), (4, 4));
        assert!(!a.overlaps(&b), "{a} overlaps {b}");
        // Squarest shape wins: 4 chips → 2×2, anchored first-fit.
        assert_eq!(a, SubMesh { row0: 0, col0: 0, rows: 2, cols: 2 });
        assert_eq!(b.row0 * p.cols + b.col0, 2, "second placement row-major");
        assert_eq!(p.free_chips(), 8);
    }

    #[test]
    fn overflow_is_typed_not_a_panic() {
        let mut p = MeshPlacement::new(2, 2);
        p.place("a", 4).unwrap();
        match p.place("b", 1) {
            Err(PlacementError::PoolExhausted { model, needed, free }) => {
                assert_eq!((model.as_str(), needed, free), ("b", 1, 0));
            }
            other => panic!("wanted PoolExhausted, got {other:?}"),
        }
        assert!(matches!(
            p.place("huge", 9),
            Err(PlacementError::LargerThanPool { needed: 9, pool: 4, .. })
        ));
        assert!(matches!(
            p.place("a", 1),
            Err(PlacementError::AlreadyPlaced { .. })
        ));
    }

    #[test]
    fn release_frees_the_slice_for_reuse() {
        let mut p = MeshPlacement::new(2, 3);
        p.place("a", 6).unwrap();
        assert!(p.place("b", 1).is_err());
        assert!(p.release("a").is_some());
        assert!(p.release("a").is_none());
        assert_eq!(p.place("b", 6).unwrap().chips(), 6);
    }

    #[test]
    fn render_shows_ownership() {
        let mut p = MeshPlacement::new(3, 4);
        p.place("alpha", 4).unwrap();
        p.place("beta", 2).unwrap();
        let art = p.render();
        assert!(art.contains("AA"), "{art}");
        assert!(art.contains("B"), "{art}");
        assert!(art.contains("alpha (2x2@(0,0))"), "{art}");
        // 3 grid rows + 2 legend lines.
        assert_eq!(art.lines().count(), 5, "{art}");
    }

    #[test]
    fn awkward_requests_round_up_to_rectangles() {
        let mut p = MeshPlacement::new(4, 4);
        // 3 chips → best rectangle is 1×3 (area 3 beats 2×2's 4).
        let s = p.place("three", 3).unwrap();
        assert_eq!(s.chips(), 3);
        // 5 chips can't be a rectangle of area 5 in a 4×4 pool except
        // 1×5 (too wide) — rounds up to 2×3.
        let s = p.place("five", 5).unwrap();
        assert_eq!((s.rows, s.cols), (2, 3));
    }
}

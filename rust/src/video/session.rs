//! Frame-to-frame inference sessions: temporal dirty-tile reuse.
//!
//! A [`FrameSession`] keeps the previous frame's per-layer activations
//! resident (the paper's stationary-FM principle extended across time)
//! and, for every new frame, recomputes only the tiles whose receptive
//! fields actually changed — splicing everything else from the cache.
//! The dirty set is tracked per tensor with [`DirtyMap`]s: pixel diffs
//! against the *effective* input mark dirty tiles, which dilate through
//! each layer's receptive field ([`DirtyMap::propagate`]), double
//! through 2× upsampling, and OR in bypass/concat contributions.
//!
//! Because dilation is exact receptive-field reachability, a clean
//! output tile's entire input window is bit-identical to the previous
//! frame — recomputing it would reproduce the cached bits — so video
//! mode is **bit-exact versus a full per-frame recompute by
//! construction**, at FP16 exactly as at f32 (every recomputed pixel's
//! rounding chain runs inside one unmodified kernel call; every clean
//! pixel is a copy). With `eps > 0` the session instead tracks the
//! *effective* input (sub-epsilon deviations are not applied), trading
//! exactness against that effective stream for more reuse.
//!
//! Both simulator backends execute the same [`VideoFramePlan`]: the
//! single-chip path through [`run_layer_rects`], the mesh path through
//! [`MeshSim::video_step`] (resident per-chip tiles, incremental halo
//! re-exchange from dirty chips only). Per-frame [`FrameStats`] report
//! the saved MACs and saved weight/feature traffic against a full
//! recompute — the numbers the `video` CLI subcommand and
//! `benches/serve.rs` sweep.

use std::fmt;
use std::sync::Arc;

use crate::engine::backend::NetworkParams;
use crate::network::{Network, TensorRef};
use crate::simulator::chip::{run_layer_rects, run_layer_threads, AccessCounts, LayerParams};
use crate::simulator::fm::FeatureMap;
use crate::simulator::mesh::{MeshError, MeshSim, MeshVideoState, VideoFramePlan, VideoStepPlan};
use crate::simulator::Precision;

use super::DirtyMap;

/// Configuration of a [`FrameSession`].
#[derive(Debug, Clone)]
pub struct VideoConfig {
    /// Simulated datapath precision.
    pub precision: Precision,
    /// Dirty-map tile edge in pixels.
    pub tile: usize,
    /// Change threshold: an input pixel deviating by more than `eps`
    /// (any channel) dirties its tile. `0.0` → bit-exact vs full
    /// recompute of the actual frames.
    pub eps: f32,
    /// Per-chip Tile-PU grid (access accounting).
    pub tiles_mn: (usize, usize),
    /// Worker threads for the first (full) frame's layer fan-out.
    pub threads: usize,
    /// `Some((rows, cols))` → multi-chip mesh execution; `None` →
    /// single-chip functional execution.
    pub mesh: Option<(usize, usize)>,
    /// FM word width for the mesh's traffic accounting.
    pub fm_bits: usize,
}

impl Default for VideoConfig {
    fn default() -> Self {
        VideoConfig {
            precision: Precision::F16,
            tile: 8,
            eps: 0.0,
            tiles_mn: (7, 7),
            threads: 1,
            mesh: None,
            fm_bits: 16,
        }
    }
}

/// What one frame cost — and what temporal reuse saved.
#[derive(Debug, Clone)]
pub struct FrameStats {
    /// 0-based frame index within the session (frame 0 is the full run).
    pub frame: usize,
    /// Fraction of input pixels inside dirty input tiles.
    pub input_dirty_fraction: f64,
    /// MAC-weighted dirty fraction across all layers — the analytic
    /// cost of this frame relative to a full recompute.
    pub mac_dirty_fraction: f64,
    /// MACs of one full-frame recompute (constant per network).
    pub total_macs: u64,
    /// Actual traffic of this frame; `saved_*` fields measure against
    /// the full-recompute baseline.
    pub access: AccessCounts,
}

impl FrameStats {
    /// `saved_macs / full-recompute MACs` — by construction equals
    /// `1 − mac_dirty_fraction` up to integer division.
    pub fn saved_mac_ratio(&self) -> f64 {
        let full = self.access.accumulates + self.access.saved_macs;
        if full == 0 {
            0.0
        } else {
            self.access.saved_macs as f64 / full as f64
        }
    }
}

/// Failures of a video session.
#[derive(Debug)]
pub enum VideoError {
    /// A frame (or the configuration) does not match the network.
    Input(String),
    /// The mesh simulator rejected the frame.
    Mesh(MeshError),
}

impl fmt::Display for VideoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VideoError::Input(m) => write!(f, "bad frame: {m}"),
            VideoError::Mesh(e) => write!(f, "mesh: {e}"),
        }
    }
}

impl std::error::Error for VideoError {}

impl From<MeshError> for VideoError {
    fn from(e: MeshError) -> Self {
        VideoError::Mesh(e)
    }
}

/// Per-backend resident state.
enum Exec {
    Functional {
        /// Cached per-step stored tensors (post-upsample grids).
        cached: Vec<FeatureMap>,
        /// Pre-upsample conv outputs for upsampling steps — dirty
        /// upsampled pixels regenerate from these.
        conv_cached: Vec<Option<FeatureMap>>,
    },
    Mesh {
        sim: MeshSim,
        state: Option<MeshVideoState>,
    },
}

/// A streaming-video inference session; see the [module docs](self).
pub struct FrameSession {
    net: Network,
    params: Arc<NetworkParams>,
    cfg: VideoConfig,
    exec: Exec,
    /// The effective resident input: equals the last frame outside
    /// sub-epsilon deviations. `None` until the first frame.
    effective: Option<FeatureMap>,
    frame: usize,
    total_macs: u64,
}

impl FrameSession {
    pub fn new(net: Network, params: Arc<NetworkParams>, cfg: VideoConfig) -> FrameSession {
        assert!(cfg.tile > 0, "tile size must be positive");
        let exec = match cfg.mesh {
            Some((rows, cols)) => {
                let mut sim = MeshSim::new(rows, cols, cfg.precision);
                sim.tiles_mn = cfg.tiles_mn;
                sim.fm_bits = cfg.fm_bits;
                Exec::Mesh { sim, state: None }
            }
            None => Exec::Functional {
                cached: Vec::new(),
                conv_cached: Vec::new(),
            },
        };
        let total_macs = net.steps.iter().map(|s| s.layer.macs()).sum();
        FrameSession {
            net,
            params,
            cfg,
            exec,
            effective: None,
            frame: 0,
            total_macs,
        }
    }

    pub fn network(&self) -> &Network {
        &self.net
    }

    /// Frames processed so far.
    pub fn frames(&self) -> usize {
        self.frame
    }

    /// Flattened input length a frame must have (`c·h·w`).
    pub fn input_len(&self) -> usize {
        self.net.in_ch * self.net.in_h * self.net.in_w
    }

    /// [`Self::process`] on a flat value buffer (the wire shape).
    pub fn process_flat(&mut self, input: &[f32]) -> Result<(Vec<f32>, FrameStats), VideoError> {
        if input.len() != self.input_len() {
            return Err(VideoError::Input(format!(
                "frame has {} values, network expects {}",
                input.len(),
                self.input_len()
            )));
        }
        let fm = FeatureMap::from_vec(
            self.net.in_ch,
            self.net.in_h,
            self.net.in_w,
            input.to_vec(),
        );
        self.process(&fm)
    }

    /// Run one frame: a full pass on the first call, change-based
    /// execution afterwards. Returns the network output (identical
    /// bits to a full recompute at `eps = 0`) and the frame's stats.
    pub fn process(&mut self, frame: &FeatureMap) -> Result<(Vec<f32>, FrameStats), VideoError> {
        let (ic, ih, iw) = (self.net.in_ch, self.net.in_h, self.net.in_w);
        if (frame.c, frame.h, frame.w) != (ic, ih, iw) {
            return Err(VideoError::Input(format!(
                "frame is {}x{}x{}, network expects {ic}x{ih}x{iw}",
                frame.c, frame.h, frame.w
            )));
        }
        if self.params.steps.len() != self.net.steps.len() {
            return Err(VideoError::Input(format!(
                "{} parameter sets for a {}-step network",
                self.params.steps.len(),
                self.net.steps.len()
            )));
        }
        if self.effective.is_none() {
            return self.first_frame(frame);
        }
        self.incremental_frame(frame)
    }

    /// Frame 0: full run, retaining every activation.
    fn first_frame(&mut self, frame: &FeatureMap) -> Result<(Vec<f32>, FrameStats), VideoError> {
        let net = &self.net;
        let params = self.params.clone();
        let (output, access) = match &mut self.exec {
            Exec::Functional { cached, conv_cached } => {
                cached.clear();
                conv_cached.clear();
                let mut access = AccessCounts::default();
                for (si, s) in net.steps.iter().enumerate() {
                    let src = resolve(frame, cached, s.src);
                    let owned_cat;
                    let src = match s.concat_extra {
                        Some(extra) => {
                            owned_cat = src.concat_channels(resolve(frame, cached, extra));
                            &owned_cat
                        }
                        None => src,
                    };
                    let byp = s.bypass.map(|b| resolve(frame, cached, b));
                    let p = &params.steps[si];
                    let lp = LayerParams {
                        layer: &s.layer,
                        stream: &p.stream,
                        gamma: &p.gamma,
                        beta: &p.beta,
                    };
                    let (out, acc) = run_layer_threads(
                        &lp,
                        src,
                        byp,
                        self.cfg.precision,
                        self.cfg.tiles_mn,
                        self.cfg.threads,
                    );
                    access.add(&acc);
                    if s.upsample2x {
                        cached.push(out.upsample2x_nearest());
                        conv_cached.push(Some(out));
                    } else {
                        cached.push(out);
                        conv_cached.push(None);
                    }
                }
                let final_out = cached.last().expect("non-empty network").data.clone();
                (final_out, access)
            }
            Exec::Mesh { sim, state } => {
                let (out, stats, st) = sim.video_init(net, &params.steps, frame)?;
                *state = Some(st);
                (out.data, stats.access)
            }
        };
        self.effective = Some(frame.clone());
        let stats = FrameStats {
            frame: self.frame,
            input_dirty_fraction: 1.0,
            mac_dirty_fraction: 1.0,
            total_macs: self.total_macs,
            access,
        };
        self.frame += 1;
        Ok((output, stats))
    }

    /// Frames 1+: diff, dilate, recompute dirty rects, splice the rest.
    fn incremental_frame(
        &mut self,
        frame: &FeatureMap,
    ) -> Result<(Vec<f32>, FrameStats), VideoError> {
        let eff = self.effective.as_mut().expect("first frame ran");
        let input_map = DirtyMap::from_diff(eff, frame, self.cfg.tile, self.cfg.eps);
        let input_dirty_fraction = input_map.dirty_pixel_fraction();
        let in_rects = input_map.rects();
        // Apply the dirty tiles to the effective input; sub-epsilon
        // deviations elsewhere are intentionally *not* applied, so the
        // resident activations stay exactly `f(effective input)`.
        for &(y0, y1, x0, x1) in &in_rects {
            for ch in 0..eff.c {
                for y in y0..y1 {
                    for x in x0..x1 {
                        eff.set(ch, y, x, frame.get(ch, y, x));
                    }
                }
            }
        }

        // Push dirtiness through the graph and build the frame plan.
        let tid = |r: TensorRef| match r {
            TensorRef::Input => 0usize,
            TensorRef::Step(i) => 1 + i,
        };
        let mut maps: Vec<DirtyMap> = vec![input_map];
        let mut plan = VideoFramePlan {
            input_rects: in_rects,
            steps: Vec::with_capacity(self.net.steps.len()),
        };
        let mut dirty_macs = 0u64;
        for s in &self.net.steps {
            let mut src_map = maps[tid(s.src)].clone();
            if let Some(extra) = s.concat_extra {
                src_map.union(&maps[tid(extra)]);
            }
            let mut conv_map = src_map.propagate(&s.layer);
            if let Some(b) = s.bypass {
                conv_map.union(&maps[tid(b)]);
            }
            dirty_macs += conv_map.dirty_pixels() * s.layer.weight_bits();
            let out_map = if s.upsample2x {
                conv_map.upsample()
            } else {
                conv_map.clone()
            };
            plan.steps.push(VideoStepPlan {
                conv_rects: conv_map.rects(),
                out_rects: out_map.rects(),
            });
            maps.push(out_map);
        }
        let mac_dirty_fraction = dirty_macs as f64 / self.total_macs.max(1) as f64;

        let net = &self.net;
        let params = self.params.clone();
        let eff = self.effective.as_ref().expect("first frame ran");
        let (output, access) = match &mut self.exec {
            Exec::Functional { cached, conv_cached } => {
                let mut access = AccessCounts::default();
                for (si, s) in net.steps.iter().enumerate() {
                    let sp = &plan.steps[si];
                    let p = &params.steps[si];
                    let lp = LayerParams {
                        layer: &s.layer,
                        stream: &p.stream,
                        gamma: &p.gamma,
                        beta: &p.beta,
                    };
                    // The output slot is disjoint from every input
                    // tensor (steps only read earlier tensors).
                    let (before, after) = cached.split_at_mut(si);
                    let slot = &mut after[0];
                    let src = resolve(eff, before, s.src);
                    let owned_cat;
                    let src = match s.concat_extra {
                        Some(extra) => {
                            owned_cat = src.concat_channels(resolve(eff, before, extra));
                            &owned_cat
                        }
                        None => src,
                    };
                    let byp = s.bypass.map(|b| resolve(eff, before, b));
                    if s.upsample2x {
                        let mut convfm = conv_cached[si].take().expect("conv cache populated");
                        access.add(&run_layer_rects(
                            &lp,
                            src,
                            byp,
                            self.cfg.precision,
                            self.cfg.tiles_mn,
                            &mut convfm,
                            &sp.conv_rects,
                        ));
                        // Regenerate dirty upsampled pixels (free
                        // replication — no counted traffic).
                        for &(y0, y1, x0, x1) in &sp.out_rects {
                            for ch in 0..convfm.c {
                                for y in y0..y1 {
                                    for x in x0..x1 {
                                        slot.set(ch, y, x, convfm.get(ch, y / 2, x / 2));
                                    }
                                }
                            }
                        }
                        conv_cached[si] = Some(convfm);
                    } else {
                        access.add(&run_layer_rects(
                            &lp,
                            src,
                            byp,
                            self.cfg.precision,
                            self.cfg.tiles_mn,
                            slot,
                            &sp.conv_rects,
                        ));
                    }
                }
                (cached.last().expect("non-empty network").data.clone(), access)
            }
            Exec::Mesh { sim, state } => {
                let st = state.as_mut().expect("first frame ran");
                let (out, stats) = sim.video_step(net, &params.steps, st, eff, &plan)?;
                (out.data, stats.access)
            }
        };
        let stats = FrameStats {
            frame: self.frame,
            input_dirty_fraction,
            mac_dirty_fraction,
            total_macs: self.total_macs,
            access,
        };
        self.frame += 1;
        Ok((output, stats))
    }
}

fn resolve<'a>(input: &'a FeatureMap, cached: &'a [FeatureMap], r: TensorRef) -> &'a FeatureMap {
    match r {
        TensorRef::Input => input,
        TensorRef::Step(i) => &cached[i],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::backend::NetworkParams;
    use crate::model::NetworkRegistry;
    use crate::video::SynthVideo;

    fn session(spec: &str, mesh: Option<(usize, usize)>, prec: Precision) -> FrameSession {
        let net = NetworkRegistry::builtin()
            .resolve(&spec.parse().unwrap())
            .unwrap()
            .network;
        let params = Arc::new(NetworkParams::seeded(&net, 8, TEST_SEED));
        FrameSession::new(
            net,
            params,
            VideoConfig {
                precision: prec,
                mesh,
                ..VideoConfig::default()
            },
        )
    }

    fn full_outputs(spec: &str, prec: Precision, frames: &[FeatureMap]) -> Vec<Vec<f32>> {
        let net = NetworkRegistry::builtin()
            .resolve(&spec.parse().unwrap())
            .unwrap()
            .network;
        let params = Arc::new(NetworkParams::seeded(&net, 8, TEST_SEED));
        let mut s = FrameSession::new(
            net,
            params,
            VideoConfig {
                precision: prec,
                ..VideoConfig::default()
            },
        );
        // A fresh session per frame == a full recompute per frame.
        frames
            .iter()
            .map(|f| {
                s.effective = None;
                s.process(f).unwrap().0
            })
            .collect()
    }

    const TEST_SEED: u64 = 0x51d30;

    #[test]
    fn functional_video_is_bit_exact_with_savings() {
        let spec = "hypernet20";
        let mut v = {
            let net = NetworkRegistry::builtin()
                .resolve(&spec.parse().unwrap())
                .unwrap()
                .network;
            SynthVideo::new(net.in_ch, net.in_h, net.in_w, 0.05, 42)
        };
        let frames: Vec<FeatureMap> = (0..4).map(|_| v.next_frame()).collect();
        let golden = full_outputs(spec, Precision::F16, &frames);
        let mut s = session(spec, None, Precision::F16);
        let mut saved_any = false;
        for (i, f) in frames.iter().enumerate() {
            let (out, stats) = s.process(f).unwrap();
            assert_eq!(out, golden[i], "frame {i} diverged");
            if i > 0 {
                assert!(stats.mac_dirty_fraction < 1.0);
                saved_any |= stats.access.saved_macs > 0;
                // Identity: actual + saved == full.
                assert_eq!(
                    stats.access.accumulates + stats.access.saved_macs,
                    golden_full_macs(&s)
                );
            }
        }
        assert!(saved_any);
    }

    fn golden_full_macs(s: &FrameSession) -> u64 {
        s.total_macs
    }

    #[test]
    fn mesh_video_is_bit_exact_vs_functional_video() {
        let spec = "hypernet20";
        let mut v = {
            let net = NetworkRegistry::builtin()
                .resolve(&spec.parse().unwrap())
                .unwrap()
                .network;
            SynthVideo::new(net.in_ch, net.in_h, net.in_w, 0.1, 7)
        };
        let frames: Vec<FeatureMap> = (0..3).map(|_| v.next_frame()).collect();
        let mut func = session(spec, None, Precision::F16);
        let mut mesh = session(spec, Some((2, 2)), Precision::F16);
        for (i, f) in frames.iter().enumerate() {
            let (a, sa) = func.process(f).unwrap();
            let (b, sb) = mesh.process(f).unwrap();
            assert_eq!(a, b, "frame {i}: mesh video diverged from functional video");
            if i > 0 {
                // Same dirty plan → same MAC count on both paths.
                assert_eq!(sa.access.accumulates, sb.access.accumulates);
                assert!(sb.access.saved_macs > 0);
            }
        }
    }

    #[test]
    fn static_stream_saves_everything_after_frame_zero() {
        let mut s = session("hypernet20", None, Precision::F32);
        let mut v = SynthVideo::new(
            s.net.in_ch,
            s.net.in_h,
            s.net.in_w,
            0.0,
            3,
        );
        let f = v.next_frame();
        let (out0, s0) = s.process(&f).unwrap();
        assert_eq!(s0.access.saved_macs, 0);
        let (out1, s1) = s.process(&f).unwrap();
        assert_eq!(out0, out1);
        assert_eq!(s1.access.accumulates, 0, "clean frame recomputed MACs");
        assert_eq!(s1.access.saved_macs, s.total_macs);
        assert_eq!(s1.access.stream_words, 0, "clean frame streamed weights");
        assert!(s1.saved_mac_ratio() > 0.999);
    }
}

//! Seeded synthetic video: a static background with moving noise
//! patches — the smart-camera workload shape (mostly-static scene, a
//! few active regions) the streaming subsystem is built for.
//!
//! Each frame is the fixed background with `np` square patches splatted
//! on top; patches drift one pixel per frame (bouncing off the edges)
//! and their contents re-randomize every frame, so the changed region
//! per frame is the union of each patch's old and new footprint —
//! `delta` of the frame area plus an O(perimeter) movement stripe. The
//! whole sequence is a pure function of `(shape, delta, seed)`
//! ([`crate::util::SplitMix64`]), so two generators with equal
//! arguments produce bit-identical streams — what the loadgen replay
//! and the bit-exactness sweeps rely on.

use crate::simulator::fm::FeatureMap;
use crate::util::SplitMix64;

struct Patch {
    y: isize,
    x: isize,
    ph: usize,
    pw: usize,
    dy: isize,
    dx: isize,
}

/// Deterministic frame-delta generator (see module docs).
pub struct SynthVideo {
    c: usize,
    h: usize,
    w: usize,
    background: Vec<f32>,
    patches: Vec<Patch>,
    rng: SplitMix64,
}

impl SynthVideo {
    /// `delta` is the target changed-area fraction per frame in
    /// `[0, 1]`: `0` produces an all-static stream, `1` re-randomizes
    /// every pixel every frame.
    pub fn new(c: usize, h: usize, w: usize, delta: f64, seed: u64) -> SynthVideo {
        assert!(c > 0 && h > 0 && w > 0, "empty frame shape");
        assert!((0.0..=1.0).contains(&delta), "delta must be in [0, 1]");
        let mut rng = SplitMix64::new(seed ^ 0x51d5_11de_0f00_d5e5);
        let background: Vec<f32> = (0..c * h * w).map(|_| rng.next_sym()).collect();
        let mut patches = Vec::new();
        if delta > 0.0 {
            // One patch up to a quarter of the frame, then two so no
            // single patch dominates; full-delta degenerates to one
            // frame-sized patch (which then cannot move — every pixel
            // changes anyway).
            let np = if delta <= 0.25 || delta >= 1.0 { 1 } else { 2 };
            let area = delta * (h * w) as f64 / np as f64;
            for _ in 0..np {
                let ph = (area.sqrt().ceil() as usize).clamp(1, h);
                let pw = ((area / ph as f64).round() as usize).clamp(1, w);
                patches.push(Patch {
                    y: rng.next_below(h - ph + 1) as isize,
                    x: rng.next_below(w - pw + 1) as isize,
                    ph,
                    pw,
                    dy: if rng.next_u64() & 1 == 0 { 1 } else { -1 },
                    dx: if rng.next_u64() & 1 == 0 { 1 } else { -1 },
                });
            }
        }
        SynthVideo {
            c,
            h,
            w,
            background,
            patches,
            rng,
        }
    }

    /// A 1-D view for wire payloads of `len` values (loadgen only knows
    /// the model's flat input length, not its `(c, h, w)`).
    pub fn flat(len: usize, delta: f64, seed: u64) -> SynthVideo {
        SynthVideo::new(1, 1, len, delta, seed)
    }

    pub fn shape(&self) -> (usize, usize, usize) {
        (self.c, self.h, self.w)
    }

    /// Produce the next frame.
    pub fn next_frame(&mut self) -> FeatureMap {
        let mut data = self.background.clone();
        let plane = self.h * self.w;
        for p in &mut self.patches {
            // Drift one pixel, bouncing off the frame edges.
            p.y += p.dy;
            if p.y < 0 || p.y as usize + p.ph > self.h {
                p.dy = -p.dy;
                p.y += 2 * p.dy;
                p.y = p.y.clamp(0, (self.h - p.ph) as isize);
            }
            p.x += p.dx;
            if p.x < 0 || p.x as usize + p.pw > self.w {
                p.dx = -p.dx;
                p.x += 2 * p.dx;
                p.x = p.x.clamp(0, (self.w - p.pw) as isize);
            }
            for c in 0..self.c {
                for y in p.y as usize..p.y as usize + p.ph {
                    let row = c * plane + y * self.w;
                    for x in p.x as usize..p.x as usize + p.pw {
                        data[row + x] = self.rng.next_sym();
                    }
                }
            }
        }
        FeatureMap::from_vec(self.c, self.h, self.w, data)
    }

    /// [`Self::next_frame`] flattened — the wire-payload shape.
    pub fn next_flat(&mut self) -> Vec<f32> {
        self.next_frame().data
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::video::DirtyMap;

    #[test]
    fn zero_delta_is_static() {
        let mut v = SynthVideo::new(3, 16, 16, 0.0, 7);
        let a = v.next_frame();
        for _ in 0..4 {
            assert_eq!(v.next_frame().data, a.data);
        }
    }

    #[test]
    fn full_delta_changes_everything() {
        let mut v = SynthVideo::new(1, 8, 8, 1.0, 7);
        let a = v.next_frame();
        let b = v.next_frame();
        let changed = a
            .data
            .iter()
            .zip(&b.data)
            .filter(|(x, y)| x != y)
            .count();
        assert!(changed as f64 > 0.99 * a.data.len() as f64);
    }

    #[test]
    fn small_delta_changes_about_delta() {
        let mut v = SynthVideo::new(1, 64, 64, 0.05, 11);
        let a = v.next_frame();
        let b = v.next_frame();
        let changed = a
            .data
            .iter()
            .zip(&b.data)
            .filter(|(x, y)| x != y)
            .count() as f64
            / a.data.len() as f64;
        // Patch area + the one-pixel movement stripe.
        assert!((0.02..=0.12).contains(&changed), "changed {changed}");
        // And the dirty tracker sees a comparably small tile fraction.
        let m = DirtyMap::from_diff(&a, &b, 8, 0.0);
        assert!(m.dirty_pixel_fraction() < 0.35);
    }

    #[test]
    fn same_seed_same_stream() {
        let mut a = SynthVideo::new(2, 12, 12, 0.3, 99);
        let mut b = SynthVideo::new(2, 12, 12, 0.3, 99);
        for _ in 0..5 {
            assert_eq!(a.next_frame().data, b.next_frame().data);
        }
        assert_eq!(SynthVideo::flat(37, 0.2, 5).next_flat().len(), 37);
    }
}

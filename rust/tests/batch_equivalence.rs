//! Property sweep of the micro-batching subsystem: for every model ×
//! precision × batch size, `Engine::infer_batch` must return per-request
//! outputs **bit-identical** to sequential `Engine::infer` while
//! streaming each weight block once per batch (`stream_words × B ==
//! sequential_stream_words`), on both simulator backends. Plus failure
//! isolation: one poisoned request fails only its own slot.

use hyperdrive::engine::{Engine, EngineError, Precision};
use hyperdrive::util::SplitMix64;

fn random_input(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..len).map(|_| rng.next_sym()).collect()
}

fn assert_batch_matches_sequential(engine: &Engine, batch: usize, seed0: u64, label: &str) {
    let inputs: Vec<Vec<f32>> = (0..batch)
        .map(|b| random_input(engine.input_len(), seed0 + b as u64))
        .collect();
    let expected: Vec<Vec<f32>> = inputs.iter().map(|x| engine.infer(x).unwrap()).collect();
    let refs: Vec<&[f32]> = inputs.iter().map(|x| x.as_slice()).collect();
    let run = engine.infer_batch(&refs);
    assert_eq!(run.outputs.len(), batch, "{label}");
    for (b, (out, want)) in run.outputs.iter().zip(&expected).enumerate() {
        assert_eq!(
            out.as_ref().unwrap(),
            want,
            "{label}: image {b} of B={batch} diverged from sequential infer"
        );
    }
    // Each layer's weight words streamed once for the whole batch.
    assert_eq!(
        run.stream_words * batch as u64,
        run.sequential_stream_words,
        "{label}: B={batch} amortization"
    );
    assert!(run.stream_words > 0, "{label}: counters wired");
    assert_eq!(
        run.stream_words_saved(),
        run.stream_words * (batch as u64 - 1),
        "{label}"
    );
}

#[test]
fn functional_batches_are_bit_exact_across_models_precisions_and_sizes() {
    for model in ["hypernet20", "resnet18@32x32"] {
        for prec in [Precision::F16, Precision::F32] {
            let engine = Engine::builder()
                .model(model)
                .precision(prec)
                .threads(3)
                .build()
                .unwrap();
            for batch in [1, 2, 3, 4, 8] {
                assert_batch_matches_sequential(
                    &engine,
                    batch,
                    900 + batch as u64,
                    &format!("functional {model} {prec:?}"),
                );
            }
        }
    }
}

#[test]
fn mesh_batches_are_bit_exact_with_amortized_stream() {
    for prec in [Precision::F16, Precision::F32] {
        let engine = Engine::builder()
            .model("hypernet20")
            .mesh(2, 2)
            .precision(prec)
            .build()
            .unwrap();
        for batch in [2, 4] {
            assert_batch_matches_sequential(
                &engine,
                batch,
                1700 + batch as u64,
                &format!("mesh 2x2 {prec:?}"),
            );
        }
    }
}

#[test]
fn functional_and_mesh_batches_agree() {
    // Same spec + seed on both backends: the batch passes must agree
    // with each other too, not just each with its own sequential path.
    let single = Engine::builder().model("hypernet20").build().unwrap();
    let mesh = Engine::builder().model("hypernet20").mesh(2, 2).build().unwrap();
    let inputs: Vec<Vec<f32>> = (0..3)
        .map(|b| random_input(single.input_len(), 4242 + b))
        .collect();
    let refs: Vec<&[f32]> = inputs.iter().map(|x| x.as_slice()).collect();
    let a = single.infer_batch(&refs);
    let b = mesh.infer_batch(&refs);
    for (x, y) in a.outputs.iter().zip(&b.outputs) {
        assert_eq!(x.as_ref().unwrap(), y.as_ref().unwrap());
    }
}

#[test]
fn one_poisoned_request_fails_only_its_own_slot() {
    let engine = Engine::builder().model("hypernet20").build().unwrap();
    let good0 = random_input(engine.input_len(), 11);
    let poison = vec![0.0f32; 7]; // wrong length
    let good1 = random_input(engine.input_len(), 12);
    let refs: Vec<&[f32]> = vec![&good0, &poison, &good1];
    let run = engine.infer_batch(&refs);
    assert_eq!(run.outputs.len(), 3);
    assert_eq!(
        run.outputs[0].as_ref().unwrap(),
        &engine.infer(&good0).unwrap()
    );
    assert_eq!(
        run.outputs[2].as_ref().unwrap(),
        &engine.infer(&good1).unwrap()
    );
    match &run.outputs[1] {
        Err(EngineError::Input(m)) => assert!(m.contains("7 values"), "{m}"),
        other => panic!("expected Input error for the poisoned slot, got {other:?}"),
    }
    // The two valid images still amortized as a batch of 2.
    assert_eq!(run.stream_words * 2, run.sequential_stream_words);
}

#[test]
fn mesh_whole_run_failures_fail_every_slot_with_the_sequential_error() {
    // 32×32 FMs do not divide over 3×3 chips: sequential infer fails
    // with a typed Unsupported error, and a batch must fail each slot
    // with that same error — never panic, never lose a ticket.
    let engine = Engine::builder().model("hypernet20").mesh(3, 3).build().unwrap();
    let input = random_input(engine.input_len(), 5);
    let sequential = engine.infer(&input).unwrap_err().to_string();
    let refs: Vec<&[f32]> = vec![&input, &input];
    let run = engine.infer_batch(&refs);
    for out in &run.outputs {
        let e = out.as_ref().unwrap_err();
        assert!(matches!(e, EngineError::Unsupported(_)), "{e}");
        assert_eq!(e.to_string(), sequential);
    }
    assert_eq!(run.stream_words, 0);
}

#[test]
fn loop_fallback_default_matches_sequential_with_zero_counters() {
    // B = 1 through the batch entry point is the degenerate batch, not
    // the fallback — counters still report one image's stream words.
    let engine = Engine::builder().model("hypernet20").build().unwrap();
    let input = random_input(engine.input_len(), 77);
    let refs: Vec<&[f32]> = vec![&input];
    let run = engine.infer_batch(&refs);
    assert_eq!(
        run.outputs[0].as_ref().unwrap(),
        &engine.infer(&input).unwrap()
    );
    assert_eq!(run.stream_words, run.sequential_stream_words);
    assert_eq!(run.stream_words_saved(), 0);
}

//! Property-based tests over the coordinator invariants: WCL liveness,
//! memory planning, scheduling, tiling and the weight stream — on
//! randomly generated (but always valid) networks.

use hyperdrive::bwn::pack_weights;
use hyperdrive::coordinator::schedule::{
    layer_cycles, schedule_network, schedule_network_mesh, DepthwisePolicy,
};
use hyperdrive::coordinator::tiling::{border_exchange_bits, per_chip_wcl_words, MeshPlan};
use hyperdrive::coordinator::{memory, wcl};
use hyperdrive::network::{ConvLayer, Network, TensorRef};
use hyperdrive::testkit;
use hyperdrive::util::SplitMix64;
use hyperdrive::ChipConfig;

/// Generate a random valid residual network (ResNet-style shape grammar:
/// stages of basic blocks with optional strided transitions).
fn random_network(rng: &mut SplitMix64) -> Network {
    let ch0 = 8 * (1 + rng.next_below(3)); // 8/16/24
    let hw0 = 8 * (1 + rng.next_below(4)); // 8..32
    let mut net = Network::new("prop", ch0, hw0, hw0);
    let mut prev = TensorRef::Input;
    let (mut ch, mut hw) = (ch0, hw0);
    let stages = 1 + rng.next_below(3);
    let mut li = 0;
    for s in 0..stages {
        let blocks = 1 + rng.next_below(2);
        for b in 0..blocks {
            let strided = s > 0 && b == 0 && hw >= 2;
            let out_ch = if strided { ch * 2 } else { ch };
            let stride = if strided { 2 } else { 1 };
            let c1 = net.push(
                ConvLayer::new(format!("l{li}a"), ch, out_ch, hw, hw, 3, stride),
                prev,
                None,
            );
            li += 1;
            let shortcut = if strided {
                let sk = net.push(
                    ConvLayer::new(format!("l{li}sk"), ch, out_ch, hw, hw, 1, 2)
                        .with_relu(false),
                    prev,
                    None,
                );
                li += 1;
                TensorRef::Step(sk)
            } else {
                prev
            };
            hw = hw.div_ceil(stride);
            ch = out_ch;
            prev = TensorRef::Step(net.push(
                ConvLayer::new(format!("l{li}b"), ch, ch, hw, hw, 3, 1)
                    .with_bypass(true)
                    .with_bypass_separate(strided),
                TensorRef::Step(c1),
                Some(shortcut),
            ));
            li += 1;
        }
    }
    net.validate().unwrap();
    net
}

#[test]
fn prop_wcl_bounds() {
    testkit::check("WCL bounds", 0x11, |rng| {
        let net = random_network(rng);
        let a = wcl::analyze(&net);
        // Lower bound: the largest single-layer in+out (non-aliased).
        let lower = net
            .steps
            .iter()
            .map(|s| {
                s.layer.in_words()
                    + if s.bypass.is_some() { 0 } else { s.layer.out_words() }
            })
            .max()
            .unwrap();
        // Upper bound: sum of all FM volumes.
        if a.wcl_words < lower {
            return Err(format!("wcl {} < lower {lower}", a.wcl_words));
        }
        if a.wcl_words > a.all_fm_words {
            return Err(format!("wcl {} > all FMs {}", a.wcl_words, a.all_fm_words));
        }
        Ok(())
    });
}

#[test]
fn prop_memory_plan_peak_equals_wcl() {
    // The allocator must realize the analysis bound exactly (§IV-B
    // realizability) on every generated network.
    testkit::check_n("plan peak == WCL", 0x22, 128, |rng| {
        let net = random_network(rng);
        let a = wcl::analyze(&net);
        let p = memory::plan(&net, a.wcl_words)
            .map_err(|e| format!("plan failed at WCL capacity: {e}"))?;
        if p.peak_words != a.wcl_words {
            return Err(format!("peak {} != wcl {}", p.peak_words, a.wcl_words));
        }
        Ok(())
    });
}

#[test]
fn prop_placements_within_capacity() {
    testkit::check_n("placements in bounds", 0x33, 128, |rng| {
        let net = random_network(rng);
        let p = memory::plan_tight(&net).map_err(|e| e.to_string())?;
        for (i, pl) in p.outputs.iter().enumerate() {
            if pl.words() != net.steps[i].layer.out_words() {
                return Err(format!("step {i}: placement words mismatch"));
            }
            for e in &pl.extents {
                if e.offset + e.words > p.capacity_words {
                    return Err(format!("step {i}: extent beyond capacity"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_schedule_cycles_consistent() {
    let cfg = ChipConfig::default();
    testkit::check("cycles vs ops bounds", 0x44, |rng| {
        let net = random_network(rng);
        let s = schedule_network(&net, &cfg, DepthwisePolicy::default());
        // Real throughput can never exceed peak.
        let opc = s.ops_per_cycle();
        if opc > cfg.ops_per_cycle() as f64 + 1e-9 {
            return Err(format!("op/cycle {opc} exceeds peak"));
        }
        // Sum of per-layer cycles equals the total.
        let sum: u64 = s.per_layer.iter().map(|(_, lc)| lc.total()).sum();
        if sum != s.total_cycles() {
            return Err("per-layer sum != total".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mesh_scheduling_never_slower_per_chip() {
    let cfg = ChipConfig::default();
    testkit::check_n("mesh speedup", 0x55, 128, |rng| {
        let net = random_network(rng);
        let s1 = schedule_network(&net, &cfg, DepthwisePolicy::default());
        let s2 = schedule_network_mesh(&net, &cfg, DepthwisePolicy::default(), 2, 2);
        if s2.total_cycles() > s1.total_cycles() {
            return Err(format!(
                "2x2 mesh per-chip cycles {} > single {}",
                s2.total_cycles(),
                s1.total_cycles()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_per_chip_wcl_monotone() {
    testkit::check_n("per-chip WCL monotone", 0x66, 96, |rng| {
        let net = random_network(rng);
        let w1 = per_chip_wcl_words(&net, 1, 1);
        let w2 = per_chip_wcl_words(&net, 2, 2);
        let w4 = per_chip_wcl_words(&net, 4, 4);
        if !(w4 <= w2 && w2 <= w1) {
            return Err(format!("not monotone: {w1} {w2} {w4}"));
        }
        // Ceil-padding bound: a 2×2 mesh holds at least a quarter.
        if w2 < w1 / 4 {
            return Err(format!("2x2 wcl {w2} below exact quarter of {w1}"));
        }
        Ok(())
    });
}

#[test]
fn prop_border_exchange_scales_with_mesh() {
    testkit::check_n("border exchange growth", 0x77, 96, |rng| {
        let net = random_network(rng);
        let plan = |r, c| MeshPlan {
            rows: r,
            cols: c,
            per_chip_wcl_words: 0,
        };
        let b1 = border_exchange_bits(&net, &plan(1, 1), 16);
        let b2 = border_exchange_bits(&net, &plan(2, 2), 16);
        let b3 = border_exchange_bits(&net, &plan(3, 3), 16);
        if b1 != 0 {
            return Err("single chip must exchange nothing".into());
        }
        if b3 < b2 {
            return Err(format!("3x3 {b3} < 2x2 {b2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_weight_stream_bits_match_layer() {
    let cfg = ChipConfig::default();
    testkit::check("stream bits = padded weight bits", 0x88, |rng| {
        let net = random_network(rng);
        let s = schedule_network(&net, &cfg, DepthwisePolicy::default());
        let padded: u64 = net
            .steps
            .iter()
            .map(|st| {
                let l = &st.layer;
                (l.n_out.div_ceil(cfg.c) * cfg.c * l.k * l.k * (l.n_in / l.groups)) as u64
            })
            .sum();
        if s.stream_bits != padded {
            return Err(format!("{} != {padded}", s.stream_bits));
        }
        Ok(())
    });
}

#[test]
fn prop_pack_weights_wire_bits() {
    testkit::check("wire bits vs weight bits", 0x99, |rng| {
        let n_in = 1 + rng.next_below(16);
        let n_out = 1 + rng.next_below(48);
        let k = if rng.next_u64() & 1 == 0 { 1 } else { 3 };
        let l = ConvLayer::new("p", n_in, n_out, 8, 8, k, 1);
        let w: Vec<f32> = (0..l.weight_bits() as usize).map(|_| rng.next_sym()).collect();
        let s = pack_weights(&l, &w, 16);
        // Wire bits are the padded count; at least the true bits.
        if s.wire_bits() < l.weight_bits() {
            return Err("wire bits below weight bits".into());
        }
        if s.wire_bits() % 16 != 0 {
            return Err("wire bits not word-aligned".into());
        }
        Ok(())
    });
}

#[test]
fn prop_layer_cycles_monotone_in_channels() {
    let cfg = ChipConfig::default();
    testkit::check("layer cycle scaling", 0xaa, |rng| {
        let n_in = 1 + rng.next_below(32);
        let n_out = 1 + rng.next_below(64);
        let hw = 4 + rng.next_below(28);
        let l1 = ConvLayer::new("a", n_in, n_out, hw, hw, 3, 1);
        let l2 = ConvLayer::new("b", n_in, 2 * n_out, hw, hw, 3, 1);
        let c1 = layer_cycles(&l1, &cfg, DepthwisePolicy::default()).conv;
        let c2 = layer_cycles(&l2, &cfg, DepthwisePolicy::default()).conv;
        if c2 < c1 {
            return Err(format!("2x channels fewer cycles: {c1} -> {c2}"));
        }
        Ok(())
    });
}

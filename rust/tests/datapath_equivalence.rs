//! The optimized datapath kernel vs the preserved per-element oracle.
//!
//! `simulator::datapath::run_tile` (channel-interleaved staging,
//! interior/border split, 8-wide blocked accumulator chains fed by the
//! per-layer `PackedLayerWeights` sign-mask planes, analytic
//! counters) must be **bit-identical** to
//! `testkit::reference_run_tile` — the pre-optimization kernel kept as
//! an independent implementation — in outputs *and* in every
//! `AccessCounts` field, at both precisions, across the whole layer
//! shape space the zoo exercises: k ∈ {1, 3}, stride ∈ {1, 2}, grouped
//! and depth-wise-ish channel layouts, odd heights/widths (ragged
//! borders), bypass/bnorm/ReLU toggles, and both single-chip
//! (full-FM) and mesh-style (sub-rectangle, offset Tile-PU grid)
//! geometries.

use hyperdrive::bwn::{pack_weights, PackedLayerWeights};
use hyperdrive::network::ConvLayer;
use hyperdrive::simulator::datapath::{analytic_counts, run_tile, Precision, TileGeom};
use hyperdrive::simulator::FeatureMap;
use hyperdrive::testkit::{self, reference_run_tile};
use hyperdrive::util::SplitMix64;

fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn fast_kernel_is_bit_identical_to_reference_oracle() {
    testkit::check_n("run_tile == reference oracle", 0xfa57, 80, |rng| {
        let k = if rng.next_u64() & 1 == 0 { 1 } else { 3 };
        let stride = if rng.next_u64() & 1 == 0 { 1 } else { 2 };
        let groups = [1usize, 1, 2, 4][rng.next_below(4)];
        let nie = 1 + rng.next_below(6);
        let n_in = groups * nie;
        let n_out = groups * (1 + rng.next_below(5));
        // Odd sizes included: ragged borders and h_out = ceil(h/stride).
        let h = (1 + rng.next_below(13)).max(stride);
        let w = (1 + rng.next_below(13)).max(stride);
        let mut l = ConvLayer::new("p", n_in, n_out, h, w, k, stride).with_groups(groups);
        if rng.next_u64() & 1 == 0 {
            l = l.with_bnorm(false);
        }
        if rng.next_u64() & 1 == 0 {
            l = l.with_relu(false);
        }
        let with_bypass = rng.next_u64() & 1 == 0;
        l = l.with_bypass(with_bypass);

        let weights: Vec<f32> = (0..n_out * nie * k * k).map(|_| rng.next_sym()).collect();
        let stream = pack_weights(&l, &weights, 16);
        // The fast path consumes the once-per-layer mask-plane expansion
        // of the packed bitplanes; the oracle decodes the stream itself.
        let packed = PackedLayerWeights::new(&stream);
        let gamma: Vec<f32> = (0..n_out).map(|_| 0.5 + rng.next_f32()).collect();
        let beta: Vec<f32> = (0..n_out).map(|_| rng.next_sym()).collect();
        let input =
            FeatureMap::from_vec(n_in, h, w, (0..n_in * h * w).map(|_| rng.next_sym()).collect());
        let (ho, wo) = (l.h_out(), l.w_out());
        let bypass_fm = with_bypass.then(|| {
            FeatureMap::from_vec(
                n_out,
                ho,
                wo,
                (0..n_out * ho * wo).map(|_| rng.next_sym()).collect(),
            )
        });

        // Half the cases run the whole FM (single-chip geometry), half
        // a sub-rectangle with a mesh-style offset Tile-PU grid.
        let geom = if rng.next_u64() & 1 == 0 {
            let (m, n) = (1 + rng.next_below(7), 1 + rng.next_below(7));
            TileGeom {
                oy0: 0,
                oy1: ho,
                ox0: 0,
                ox1: wo,
                iy0: 0,
                ix0: 0,
                tile_h: ho.div_ceil(m).max(1),
                tile_w: wo.div_ceil(n).max(1),
                in_tile_h: h.div_ceil(m).max(1),
                in_tile_w: w.div_ceil(n).max(1),
            }
        } else {
            let oy0 = rng.next_below(ho);
            let oy1 = oy0 + 1 + rng.next_below(ho - oy0);
            let ox0 = rng.next_below(wo);
            let ox1 = ox0 + 1 + rng.next_below(wo - ox0);
            TileGeom {
                oy0,
                oy1,
                ox0,
                ox1,
                iy0: (oy0 * stride) as isize,
                ix0: (ox0 * stride) as isize,
                tile_h: 1 + rng.next_below(3),
                tile_w: 1 + rng.next_below(3),
                in_tile_h: 1 + rng.next_below(3),
                in_tile_w: 1 + rng.next_below(3),
            }
        };
        // Sometimes a partial channel range (the threaded callers').
        let co0 = rng.next_below(n_out);
        let co1 = co0 + 1 + rng.next_below(n_out - co0);

        for prec in [Precision::F16, Precision::F32] {
            let mut fast = vec![f32::NAN; n_out * ho * wo];
            let mut oracle = vec![f32::NAN; n_out * ho * wo];
            let acc_fast = run_tile(
                &l,
                &packed,
                &gamma,
                &beta,
                (co0, co1),
                &input,
                bypass_fm.as_ref(),
                prec,
                &geom,
                &mut |co, oy, ox, v| fast[(co * ho + oy) * wo + ox] = v,
            );
            let acc_oracle = reference_run_tile(
                &l,
                &stream,
                &gamma,
                &beta,
                (co0, co1),
                &input,
                bypass_fm.as_ref(),
                prec,
                &geom,
                &mut |co, oy, ox, v| oracle[(co * ho + oy) * wo + ox] = v,
            );
            if !bits_equal(&fast, &oracle) {
                return Err(format!(
                    "{prec:?} outputs diverged: k={k} stride={stride} groups={groups} \
                     {n_in}→{n_out} {h}×{w} geom={geom:?} co=[{co0},{co1})"
                ));
            }
            if acc_fast != acc_oracle {
                return Err(format!(
                    "{prec:?} counters diverged:\n fast   {acc_fast:?}\n oracle {acc_oracle:?}\n \
                     k={k} stride={stride} groups={groups} {n_in}→{n_out} {h}×{w} geom={geom:?}"
                ));
            }
            // The closed-form counters *are* what run_tile returns;
            // assert them against the counted oracle explicitly so the
            // property still bites if run_tile ever grows its own
            // counting again.
            let analytic = analytic_counts(&l, (co0, co1), with_bypass, &geom);
            if analytic != acc_oracle {
                return Err(format!(
                    "analytic counters != counted oracle:\n analytic {analytic:?}\n \
                     oracle   {acc_oracle:?}"
                ));
            }
        }
        Ok(())
    });
}

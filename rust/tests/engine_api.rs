//! Integration tests of the unified `Engine` façade: builder
//! validation, cross-backend bit-exactness and concurrent serving.

use std::sync::Arc;

use hyperdrive::engine::{Engine, EngineError, NetworkParams, Precision, ServeOptions};
use hyperdrive::model;
use hyperdrive::util::SplitMix64;

fn random_input(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..len).map(|_| rng.next_sym()).collect()
}

#[test]
fn builder_requires_a_network() {
    let err = Engine::builder().build().unwrap_err();
    assert!(matches!(err, EngineError::Builder(_)), "{err}");
    assert!(err.to_string().contains("network"), "{err}");
}

#[test]
fn mesh_without_network_is_a_builder_error() {
    let err = Engine::builder().mesh(2, 2).build().unwrap_err();
    assert!(matches!(err, EngineError::Builder(_)), "{err}");
}

#[test]
fn forced_backend_rejects_conflicting_knobs() {
    use hyperdrive::engine::BackendKind;
    // A mesh request must not be silently ignored by a forced
    // functional backend (it would report 1x1-plan numbers).
    let err = Engine::builder()
        .network(model::network("hypernet20").unwrap())
        .mesh(2, 2)
        .backend(BackendKind::Functional)
        .build()
        .unwrap_err();
    assert!(matches!(err, EngineError::Builder(_)), "{err}");
    let err = Engine::builder()
        .network(model::network("hypernet20").unwrap())
        .artifacts("artifacts")
        .backend(BackendKind::Functional)
        .build()
        .unwrap_err();
    assert!(matches!(err, EngineError::Builder(_)), "{err}");
}

#[test]
fn oversubscribed_mesh_reports_fmm_overflow() {
    // ResNet-34 @ 2048×1024 needs ~50 chips; a 2×2 mesh cannot hold the
    // per-chip WCL slice and must fail with the structured error.
    let err = Engine::builder()
        .network(model::network("resnet34@1024x2048").unwrap())
        .mesh(2, 2)
        .build()
        .unwrap_err();
    match err {
        EngineError::FmmOverflow {
            rows,
            cols,
            per_chip_wcl_words,
            fmm_words,
        } => {
            assert_eq!((rows, cols), (2, 2));
            assert!(per_chip_wcl_words > fmm_words as u64);
        }
        other => panic!("expected FmmOverflow, got {other}"),
    }
}

#[test]
fn auto_mesh_plans_the_paper_configuration() {
    let engine = Engine::builder()
        .network(model::network("resnet34@1024x2048").unwrap())
        .auto_mesh()
        .build()
        .unwrap();
    let rep = engine.report();
    assert_eq!((rep.plan.rows, rep.plan.cols), (5, 10), "paper's Tbl V mesh");
    assert!(rep.plan.per_chip_wcl_words <= rep.chip.fmm_words as u64);
    assert!(rep.border_bits > 0);
}

#[test]
fn functional_and_mesh_backends_match_bit_exactly() {
    // The acceptance check: same network, same parameters, FP16 on both
    // backends → identical logits, bit for bit.
    let net = model::network("hypernet20").unwrap();
    let params = Arc::new(NetworkParams::seeded(&net, 16, 0xE2E));
    let functional = Engine::builder()
        .network(net.clone())
        .params(params.clone())
        .precision(Precision::F16)
        .build()
        .unwrap();
    let mesh = Engine::builder()
        .network(net)
        .params(params)
        .mesh(2, 2)
        .precision(Precision::F16)
        .build()
        .unwrap();
    let input = random_input(functional.input_len(), 5);
    let a = functional.infer(&input).unwrap();
    let b = mesh.infer(&input).unwrap();
    assert_eq!(a, b, "functional vs mesh logits must be bit-exact");
    let stats = mesh.mesh_stats().expect("mesh stats recorded");
    assert!(stats.border_bits > 0 && stats.corner_bits > 0);
}

#[test]
fn concurrent_serving_matches_sequential() {
    let engine = Engine::builder()
        .network(model::network("hypernet20").unwrap())
        .seed(11)
        .build()
        .unwrap();
    let inputs: Vec<Vec<f32>> = (0..6)
        .map(|i| random_input(engine.input_len(), 100 + i as u64))
        .collect();
    let seq_opts = ServeOptions {
        workers: 1,
        ..ServeOptions::default()
    };
    let conc_opts = ServeOptions {
        workers: 4,
        queue_depth: 2,
    };
    let (seq, s1) = engine.serve(&inputs, &seq_opts).unwrap().outputs().unwrap();
    let (conc, s4) = engine.serve(&inputs, &conc_opts).unwrap().outputs().unwrap();
    assert_eq!(seq, conc, "worker pool must not change outputs or order");
    assert_eq!(s1.requests, 6);
    assert_eq!(s1.completed, 6);
    assert_eq!(s1.workers, 1);
    assert_eq!(s4.workers, 4);
    assert!(s4.p99_ms >= s4.p50_ms && s4.p50_ms > 0.0);
    assert!(s4.ops_per_s > 0.0);
}

#[test]
fn serve_rejects_zero_knobs_with_typed_errors() {
    // Like EngineBuilder::threads(0): a zero worker count or queue
    // depth is a typed error, not a silent clamp.
    let engine = Engine::builder()
        .network(model::network("hypernet20").unwrap())
        .build()
        .unwrap();
    let inputs = vec![vec![0.0f32; engine.input_len()]];
    for opts in [
        ServeOptions {
            workers: 0,
            queue_depth: 8,
        },
        ServeOptions {
            workers: 2,
            queue_depth: 0,
        },
    ] {
        let err = engine.serve(&inputs, &opts).unwrap_err();
        assert!(matches!(err, EngineError::Builder(_)), "{err}");
    }
}

#[test]
fn trace_hook_sees_every_layer() {
    let engine = Engine::builder().network(model::network("hypernet20").unwrap()).build().unwrap();
    let input = random_input(engine.input_len(), 3);
    let mut seen: Vec<(usize, String, (usize, usize, usize))> = Vec::new();
    let out = engine
        .infer_traced(&input, &mut |t| {
            seen.push((t.step, t.layer.to_string(), t.shape));
        })
        .unwrap();
    assert_eq!(seen.len(), engine.network().steps.len());
    assert_eq!(seen[0].1, "s1b0c1");
    let (c, h, w) = seen.last().unwrap().2;
    assert_eq!((c, h, w), (64, 8, 8));
    assert_eq!(out.len(), c * h * w);
}

#[test]
fn mesh_trace_matches_functional_trace() {
    let net = model::network("hypernet20").unwrap();
    let params = Arc::new(NetworkParams::seeded(&net, 16, 77));
    let functional = Engine::builder()
        .network(net.clone())
        .params(params.clone())
        .build()
        .unwrap();
    let mesh = Engine::builder()
        .network(net)
        .params(params)
        .mesh(4, 4)
        .build()
        .unwrap();
    let input = random_input(functional.input_len(), 9);
    let mut func_fms: Vec<Vec<f32>> = Vec::new();
    functional
        .infer_traced(&input, &mut |t| func_fms.push(t.output.to_vec()))
        .unwrap();
    let mut step = 0usize;
    mesh.infer_traced(&input, &mut |t| {
        assert_eq!(t.output, &func_fms[t.step][..], "step {} diverged", t.step);
        step += 1;
    })
    .unwrap();
    assert_eq!(step, func_fms.len());
}

#[test]
fn wrong_input_length_is_a_clean_error() {
    let engine = Engine::builder().network(model::network("hypernet20").unwrap()).build().unwrap();
    let err = engine.infer(&[0.0; 7]).unwrap_err();
    assert!(matches!(err, EngineError::Input(_)), "{err}");
    let err = engine
        .serve(&[vec![0.0; 7]], &ServeOptions::default())
        .unwrap_err();
    assert!(matches!(err, EngineError::Input(_)), "{err}");
}

#[test]
fn indivisible_mesh_is_a_clean_error() {
    // 32×32 FMs do not divide over 3×3 chips: build (analytic) succeeds,
    // inference reports Unsupported instead of panicking.
    let engine = Engine::builder()
        .network(model::network("hypernet20").unwrap())
        .mesh(3, 3)
        .build()
        .unwrap();
    let input = random_input(engine.input_len(), 1);
    let err = engine.infer(&input).unwrap_err();
    assert!(matches!(err, EngineError::Unsupported(_)), "{err}");
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn pjrt_backend_degrades_cleanly_without_the_feature() {
    let err = Engine::builder().artifacts("artifacts").build().unwrap_err();
    assert!(matches!(err, EngineError::Unavailable(_)), "{err}");
}

//! Chaos/fault-injection integration tests: seeded [`FaultPlan`]s
//! drive deterministic failures through the mesh simulator and the
//! serving stack, and the resilience machinery (typed mesh errors,
//! deadlines, watchdog, graceful shutdown) must absorb them — every
//! admitted ticket resolves, shutdown never hangs, and identical
//! seeds reproduce identical fault counters.

use std::sync::Arc;
use std::time::{Duration, Instant};

use hyperdrive::bwn::pack_weights;
use hyperdrive::engine::{InferRequest, InferenceService, ServeError, Ticket};
use hyperdrive::faults::{FaultKind, FaultPlan, Trigger};
use hyperdrive::network::{ConvLayer, Network, TensorRef};
use hyperdrive::simulator::mesh::{MeshError, MeshSim, StepParams};
use hyperdrive::simulator::{FeatureMap, Precision};
use hyperdrive::util::SplitMix64;

/// Smallest mesh-runnable network with real border exchange: two
/// 3×3 convs over an 8×8 FM on a 2×2 mesh. Two layers matter — the
/// mesh only runs an exchange phase for tensors a *later* step
/// consumes with a halo, so a single-layer net never exchanges.
fn tiny_net() -> Network {
    let mut net = Network::new("chaos-net", 4, 8, 8);
    let c0 = net.push(
        ConvLayer::new("c0", 4, 4, 8, 8, 3, 1),
        TensorRef::Input,
        None,
    );
    net.push(
        ConvLayer::new("c1", 4, 4, 8, 8, 3, 1),
        TensorRef::Step(c0),
        None,
    );
    net.validate().expect("valid network");
    net
}

fn tiny_params(net: &Network, rng: &mut SplitMix64) -> Vec<StepParams> {
    net.steps
        .iter()
        .map(|s| {
            let l = &s.layer;
            let nie = l.n_in / l.groups;
            let w: Vec<f32> = (0..l.n_out * nie * l.k * l.k)
                .map(|_| rng.next_sym())
                .collect();
            let fan_in = (nie * l.k * l.k) as f32;
            StepParams {
                stream: pack_weights(l, &w, 16),
                gamma: (0..l.n_out)
                    .map(|_| (0.1 + 0.4 * rng.next_f32()) / fan_in)
                    .collect(),
                beta: (0..l.n_out).map(|_| 0.1 * rng.next_sym()).collect(),
            }
        })
        .collect()
}

fn tiny_input(net: &Network, rng: &mut SplitMix64) -> FeatureMap {
    FeatureMap::from_vec(
        net.in_ch,
        net.in_h,
        net.in_w,
        (0..net.in_ch * net.in_h * net.in_w)
            .map(|_| rng.next_sym())
            .collect(),
    )
}

#[test]
fn mesh_chip_death_is_a_typed_error() {
    let mut rng = SplitMix64::new(0xdead);
    let net = tiny_net();
    let params = tiny_params(&net, &mut rng);
    let input = tiny_input(&net, &mut rng);
    let mut sim = MeshSim::new(2, 2, Precision::F32);
    // Site seq for chip death is `step * rows * cols + chip`; Nth(0)
    // kills chip (0, 0) before step 0.
    let plan = Arc::new(FaultPlan::new(1).rule(FaultKind::ChipDeath, Trigger::Nth(0)));
    sim.faults = Some(plan.clone());
    match sim.run_network(&net, &params, &input) {
        Err(MeshError::ChipDead { chip, step }) => {
            assert_eq!(chip, (0, 0));
            assert_eq!(step, 0);
        }
        other => panic!("expected ChipDead, got {other:?}"),
    }
    assert_eq!(plan.counters().chip_deaths, 1);
}

#[test]
fn mesh_halo_corruption_fails_the_checksum() {
    let mut rng = SplitMix64::new(0xc0de);
    let net = tiny_net();
    let params = tiny_params(&net, &mut rng);
    let input = tiny_input(&net, &mut rng);
    let mut sim = MeshSim::new(2, 2, Precision::F32);
    let plan = Arc::new(FaultPlan::new(2).rule(FaultKind::CorruptExchange, Trigger::Always));
    sim.faults = Some(plan.clone());
    match sim.run_network(&net, &params, &input) {
        Err(MeshError::CorruptExchange { .. }) => {}
        other => panic!("expected CorruptExchange, got {other:?}"),
    }
    assert!(plan.counters().corrupt_exchanges >= 1);
}

#[test]
fn empty_fault_plan_is_bit_exact_with_no_plan() {
    let mut rng = SplitMix64::new(0x5eed);
    let net = tiny_net();
    let params = tiny_params(&net, &mut rng);
    let input = tiny_input(&net, &mut rng);
    let clean = {
        let sim = MeshSim::new(2, 2, Precision::F32);
        sim.run_network(&net, &params, &input).expect("clean run").0
    };
    let mut sim = MeshSim::new(2, 2, Precision::F32);
    let plan = Arc::new(FaultPlan::new(99)); // seeded, zero rules
    sim.faults = Some(plan.clone());
    let (out, stats) = sim.run_network(&net, &params, &input).expect("no-op plan run");
    assert_eq!(out.max_abs_diff(&clean), 0.0);
    assert!(stats.flags.is_quiescent());
    assert_eq!(plan.counters().total(), 0);
}

/// Build a single-model service over `hypernet20` with the given
/// chaos plan and worker count.
fn chaos_service(plan: Arc<FaultPlan>, workers: usize, watchdog_ms: u64) -> InferenceService {
    InferenceService::builder()
        .model_spec("hypernet20")
        .workers(workers)
        .queue_depth(64)
        .faults(plan)
        .watchdog_ms(watchdog_ms)
        .build()
        .expect("service build")
}

/// Run `n` requests through a chaos service and wait every ticket.
/// Returns how many resolved Ok (the rest must carry typed errors).
fn soak(svc: &InferenceService, n: u64) -> u64 {
    let len = svc.input_len("hypernet20").expect("hosted model");
    let mut rng = SplitMix64::new(0x50a6);
    let tickets: Vec<Ticket> = (0..n)
        .map(|i| {
            let input: Vec<f32> = (0..len).map(|_| rng.next_sym()).collect();
            svc.submit(InferRequest {
                model: "hypernet20".into(),
                input: input.into(),
                id: i,
                deadline_ms: None,
            })
            .expect("admission (queue is deep enough)")
        })
        .collect();
    let mut ok = 0;
    for t in tickets {
        match t.wait() {
            Ok(_) => ok += 1,
            Err(
                ServeError::WorkerStalled { .. }
                | ServeError::DeadlineExceeded { .. }
                | ServeError::ShuttingDown,
            ) => {}
            Err(other) => panic!("unexpected chaos-soak error: {other}"),
        }
    }
    ok
}

#[test]
fn chaos_soak_resolves_every_ticket_and_reproduces_counters() {
    // Probability-triggered slow batches and short stalls, keyed by
    // request id: the soak must resolve all 48 tickets, and a second
    // service built from an identically-seeded plan must inject
    // exactly the same faults.
    let build_plan = || {
        Arc::new(
            FaultPlan::new(0xCAFE)
                .rule(FaultKind::SlowModel { ms: 4 }, Trigger::Prob(0.3))
                .rule(FaultKind::WorkerStall { ms: 8 }, Trigger::Prob(0.2)),
        )
    };
    let mut counter_snapshots = Vec::new();
    for _ in 0..2 {
        let plan = build_plan();
        let svc = chaos_service(plan.clone(), 4, 5_000);
        let ok = soak(&svc, 48);
        // Stalls here are 8 ms against a 5 s watchdog: nothing gets
        // abandoned, so every request must succeed.
        assert_eq!(ok, 48);
        let metrics = svc.shutdown();
        let counters = plan.counters();
        assert!(counters.total() > 0, "chaos plan never fired: {counters}");
        assert_eq!(counters.chip_deaths, 0);
        assert_eq!(counters.connection_drops, 0);
        assert_eq!(
            metrics.total_faults_injected(),
            counters.slow_models + counters.worker_stalls,
            "service metrics must agree with the plan's ledger"
        );
        counter_snapshots.push(format!("{counters}"));
    }
    assert_eq!(
        counter_snapshots[0], counter_snapshots[1],
        "identical seeds must inject identical faults"
    );
}

#[test]
fn watchdog_fails_stalled_work_and_shutdown_stays_fast() {
    // Every executed batch stalls 30 s; the 100 ms watchdog must fail
    // the in-flight ticket with WorkerStalled (not hang the waiter),
    // and shutdown must detach the stuck worker instead of joining it.
    let plan = Arc::new(FaultPlan::new(9).rule(
        FaultKind::WorkerStall { ms: 30_000 },
        Trigger::Always,
    ));
    let svc = chaos_service(plan, 1, 100);
    let len = svc.input_len("hypernet20").expect("hosted model");
    let ticket = svc
        .submit(InferRequest {
            model: "hypernet20".into(),
            input: vec![0.5f32; len].into(),
            id: 1,
            deadline_ms: None,
        })
        .expect("admission");
    let t0 = Instant::now();
    match ticket.wait() {
        Err(ServeError::WorkerStalled { model, stalled_ms }) => {
            assert_eq!(model, "hypernet20");
            assert!(stalled_ms >= 100, "stalled_ms = {stalled_ms}");
        }
        other => panic!("expected WorkerStalled, got {other:?}"),
    }
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "ticket.wait() should resolve at watchdog speed, took {:?}",
        t0.elapsed()
    );
    let t1 = Instant::now();
    let metrics = svc.shutdown();
    assert!(
        t1.elapsed() < Duration::from_secs(1),
        "shutdown must detach the stalled worker, took {:?}",
        t1.elapsed()
    );
    assert_eq!(metrics.total_failed(), 1);
}

#[test]
fn fault_plan_parse_round_trips_the_cli_grammar() {
    // The `--chaos` CLI spec: bare seed expands to the default mix…
    let plan = FaultPlan::parse("42").expect("bare seed");
    assert_eq!(plan.seed(), 42);
    assert!(!plan.is_empty());
    // …and the full grammar pins kinds and triggers.
    let plan =
        FaultPlan::parse("7:stall:50@prob:0.05,drop@every:10,chip-death@nth:3").expect("full spec");
    assert_eq!(plan.seed(), 7);
    assert!(plan.worker_stall(u64::MAX).is_none() || plan.worker_stall(u64::MAX) == Some(50));
    assert!(FaultPlan::parse("x:stall").is_err());
    assert!(FaultPlan::parse("7:warp@always").is_err());
    assert!(FaultPlan::parse("7:drop@prob:1.5").is_err());
}

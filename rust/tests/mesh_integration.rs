//! Mesh-simulator integration properties: on random residual networks
//! and random mesh shapes, the distributed execution with real
//! border/corner exchange must be bit-exact vs the single-chip
//! reference, and its measured traffic must equal the coordinator's
//! analytic accounting (the Fig 11 model).

use hyperdrive::bwn::pack_weights;
use hyperdrive::coordinator::tiling::{border_exchange_bits, MeshPlan};
use hyperdrive::network::{ConvLayer, Network, TensorRef};
use hyperdrive::simulator::mesh::{MeshSim, StepParams};
use hyperdrive::simulator::{self, FeatureMap, Precision};
use hyperdrive::testkit;
use hyperdrive::util::SplitMix64;

/// Random residual network with dims divisible by 4 (mesh constraint).
fn random_network(rng: &mut SplitMix64) -> Network {
    let ch0 = 4 * (1 + rng.next_below(3));
    let hw0 = 8 * (1 + rng.next_below(2)); // 8 or 16
    let mut net = Network::new("mesh-prop", ch0, hw0, hw0);
    let mut prev = TensorRef::Input;
    let (mut ch, mut hw) = (ch0, hw0);
    let mut li = 0;
    for s in 0..2usize {
        for b in 0..(1 + rng.next_below(2)) {
            let strided = s > 0 && b == 0;
            let out_ch = if strided { ch * 2 } else { ch };
            let stride = if strided { 2 } else { 1 };
            let c1 = net.push(
                ConvLayer::new(format!("m{li}a"), ch, out_ch, hw, hw, 3, stride),
                prev,
                None,
            );
            li += 1;
            let shortcut = if strided {
                let sk = net.push(
                    ConvLayer::new(format!("m{li}sk"), ch, out_ch, hw, hw, 1, 2)
                        .with_relu(false),
                    prev,
                    None,
                );
                li += 1;
                TensorRef::Step(sk)
            } else {
                prev
            };
            hw = hw.div_ceil(stride);
            ch = out_ch;
            prev = TensorRef::Step(net.push(
                ConvLayer::new(format!("m{li}b"), ch, ch, hw, hw, 3, 1).with_bypass(true),
                TensorRef::Step(c1),
                Some(shortcut),
            ));
            li += 1;
        }
    }
    net.validate().unwrap();
    net
}

fn random_params(net: &Network, rng: &mut SplitMix64) -> Vec<StepParams> {
    net.steps
        .iter()
        .map(|s| {
            let l = &s.layer;
            let nie = l.n_in / l.groups;
            let w: Vec<f32> = (0..l.n_out * nie * l.k * l.k).map(|_| rng.next_sym()).collect();
            // α/fan-in scaling keeps FP16 activations bounded (see
            // simulator::mesh tests).
            let fan_in = (nie * l.k * l.k) as f32;
            StepParams {
                stream: pack_weights(l, &w, 16),
                gamma: (0..l.n_out)
                    .map(|_| (0.1 + 0.4 * rng.next_f32()) / fan_in)
                    .collect(),
                beta: (0..l.n_out).map(|_| 0.1 * rng.next_sym()).collect(),
            }
        })
        .collect()
}

fn single_chip(net: &Network, params: &[StepParams], input: &FeatureMap, prec: Precision) -> FeatureMap {
    let mut outs: Vec<FeatureMap> = Vec::new();
    for (i, s) in net.steps.iter().enumerate() {
        let src = match s.src {
            TensorRef::Input => input,
            TensorRef::Step(j) => &outs[j],
        };
        let byp = s.bypass.map(|b| match b {
            TensorRef::Input => input.clone(),
            TensorRef::Step(j) => outs[j].clone(),
        });
        let lp = simulator::chip::LayerParams {
            layer: &s.layer,
            stream: &params[i].stream,
            gamma: &params[i].gamma,
            beta: &params[i].beta,
        };
        let (o, _) = simulator::run_layer(&lp, src, byp.as_ref(), prec, (7, 7));
        outs.push(o);
    }
    outs.pop().unwrap()
}

#[test]
fn prop_mesh_bit_exact_vs_single_chip() {
    testkit::check_n("mesh == single chip", 0x3e5a, 12, |rng| {
        let net = random_network(rng);
        let params = random_params(&net, rng);
        let input = FeatureMap::from_vec(
            net.in_ch,
            net.in_h,
            net.in_w,
            (0..net.in_ch * net.in_h * net.in_w).map(|_| rng.next_sym()).collect(),
        );
        let prec = if rng.next_u64() & 1 == 0 {
            Precision::F16
        } else {
            Precision::F32
        };
        let want = single_chip(&net, &params, &input, prec);
        // Mesh shapes dividing 8: 2×2, 2×4, 4×2, 4×4.
        let shapes = [(2usize, 2usize), (2, 4), (4, 2), (4, 4)];
        let (r, c) = shapes[rng.next_below(shapes.len())];
        let sim = MeshSim::new(r, c, prec);
        let (out, stats) = sim.run_network(&net, &params, &input).unwrap();
        if out.max_abs_diff(&want) != 0.0 {
            return Err(format!("{r}x{c} mesh diverged"));
        }
        if !stats.flags.is_quiescent() {
            return Err("exchange protocol not quiescent".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mesh_traffic_matches_analytic_model() {
    testkit::check_n("mesh traffic == Fig 11 accounting", 0xacc7, 12, |rng| {
        let net = random_network(rng);
        let params = random_params(&net, rng);
        let input = FeatureMap::from_vec(
            net.in_ch,
            net.in_h,
            net.in_w,
            (0..net.in_ch * net.in_h * net.in_w).map(|_| rng.next_f32()).collect(),
        );
        let (r, c) = [(2usize, 2usize), (2, 4), (4, 4)][rng.next_below(3)];
        let sim = MeshSim::new(r, c, Precision::F32);
        let (_, stats) = sim.run_network(&net, &params, &input).unwrap();
        let plan = MeshPlan {
            rows: r,
            cols: c,
            per_chip_wcl_words: 0,
        };
        let analytic = border_exchange_bits(&net, &plan, 16);
        let measured = stats.border_bits + stats.corner_bits;
        if measured != analytic {
            return Err(format!("measured {measured} != analytic {analytic}"));
        }
        Ok(())
    });
}

#[test]
fn fault_injection_poisons_output() {
    // Dropping a single border transfer must corrupt the result (the
    // NaN-initialized halo propagates) — proving the bit-exactness
    // checks actually exercise the exchange protocol.
    let mut rng = SplitMix64::new(0xbad);
    let net = random_network(&mut rng);
    let params = random_params(&net, &mut rng);
    let input = FeatureMap::from_vec(
        net.in_ch,
        net.in_h,
        net.in_w,
        (0..net.in_ch * net.in_h * net.in_w).map(|_| rng.next_sym()).collect(),
    );
    let good = {
        let sim = MeshSim::new(2, 2, Precision::F32);
        sim.run_network(&net, &params, &input).unwrap().0
    };
    let mut sim = MeshSim::new(2, 2, Precision::F32);
    sim.fault_drop_send = Some(5);
    let (bad, _) = sim.run_network(&net, &params, &input).unwrap();
    let diff = bad.max_abs_diff(&good);
    assert!(
        diff.is_nan() || diff > 0.0,
        "dropped transfer went unnoticed (diff {diff})"
    );
}

#[test]
fn mesh_flit_count_is_4bit_serialization() {
    let mut rng = SplitMix64::new(0xf117);
    let net = random_network(&mut rng);
    let params = random_params(&net, &mut rng);
    let input = FeatureMap::from_vec(
        net.in_ch,
        net.in_h,
        net.in_w,
        (0..net.in_ch * net.in_h * net.in_w).map(|_| rng.next_sym()).collect(),
    );
    let sim = MeshSim::new(2, 2, Precision::F32);
    let (_, stats) = sim.run_network(&net, &params, &input).unwrap();
    // 16-bit pixels → 4 flits per hop.
    assert_eq!(stats.flits * 4, stats.border_bits + stats.corner_bits);
}

//! Integration tests of the typed model-description API: spec parsing,
//! registry resolution, shape-inference validation, the engine builder's
//! `.model(..)` entry point and cross-backend bit-exactness on a
//! registry-resolved model.

use hyperdrive::engine::{Engine, EngineError, Precision};
use hyperdrive::model::{self, ModelError, ModelSpec, NetworkRegistry, SpecError};
use hyperdrive::util::SplitMix64;

fn random_input(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..len).map(|_| rng.next_sym()).collect()
}

#[test]
fn spec_grammar_round_trips() {
    let spec: ModelSpec = "resnet34@512x1024".parse().unwrap();
    assert_eq!(
        spec,
        ModelSpec::Registry {
            name: "resnet34".into(),
            resolution: Some((512, 1024)),
        }
    );
    assert_eq!(spec.to_string().parse::<ModelSpec>().unwrap(), spec);

    assert!(matches!(
        "".parse::<ModelSpec>().unwrap_err(),
        SpecError::Empty
    ));
    assert!(matches!(
        "resnet34@huge".parse::<ModelSpec>().unwrap_err(),
        SpecError::BadResolution { .. }
    ));
}

#[test]
fn registry_lookup_failure_is_typed_and_lists_models() {
    let err = model::resolve("not-a-network").unwrap_err();
    match &err {
        ModelError::UnknownModel { name, known } => {
            assert_eq!(name, "not-a-network");
            assert!(known.iter().any(|n| n == "hypernet20"), "{known:?}");
        }
        other => panic!("expected UnknownModel, got {other}"),
    }
}

#[test]
fn shape_inference_validates_resolutions() {
    // A divisible resolution resolves, and the entry's inferred output
    // shape matches the built network.
    let reg = NetworkRegistry::builtin();
    let m = reg.resolve_str("resnet34@512x1024").unwrap();
    assert_eq!(
        m.network.out_shape(),
        reg.get("resnet34").unwrap().output_shape(512, 1024)
    );
    assert_eq!(m.network.out_shape(), (512, 16, 32));

    // A non-divisible one is a typed error, not silent truncation.
    match reg.resolve_str("resnet34@510x1024").unwrap_err() {
        ModelError::Resolution(e) => {
            assert_eq!((e.h, e.w), (510, 1024));
            assert_ne!(510 % e.granularity, 0);
        }
        other => panic!("expected Resolution, got {other}"),
    }
}

#[test]
fn engine_builder_resolves_model_specs() {
    let engine = Engine::builder().model("hypernet20").build().unwrap();
    assert_eq!(engine.network().name, "HyperNet-20");
    assert_eq!(engine.input_len(), 16 * 32 * 32);

    let err = Engine::builder().model("resnet99").build().unwrap_err();
    assert!(matches!(err, EngineError::Model(ModelError::UnknownModel { .. })), "{err}");

    let err = Engine::builder().model("resnet34@@").build().unwrap_err();
    assert!(matches!(err, EngineError::Model(ModelError::Spec(_))), "{err}");

    let err = Engine::builder().model("resnet34@225x225").build().unwrap_err();
    assert!(matches!(err, EngineError::Model(ModelError::Resolution(_))), "{err}");
}

#[test]
fn model_and_network_conflict_is_a_builder_error() {
    let net = model::network("hypernet20").unwrap();
    let err = Engine::builder()
        .model("hypernet20")
        .network(net)
        .build()
        .unwrap_err();
    assert!(matches!(err, EngineError::Builder(_)), "{err}");
}

#[test]
fn custom_registry_overrides_builtin() {
    let mut reg = NetworkRegistry::builtin();
    let mut entry = reg.get("resnet34").unwrap().clone();
    entry.default_resolution = (64, 64);
    reg.register(entry);
    let engine = Engine::builder()
        .registry(reg)
        .model("resnet34")
        .build()
        .unwrap();
    // 64×64 image → 16×16 on-chip input FM.
    assert_eq!(engine.input_len(), 64 * 16 * 16);
}

#[test]
fn functional_vs_mesh_bitexact_on_a_registry_model() {
    // The same spec + seed resolves to identical networks and seeded
    // parameters on both simulator backends → bit-exact logits.
    let functional = Engine::builder()
        .model("hypernet20")
        .seed(0xB17)
        .precision(Precision::F16)
        .build()
        .unwrap();
    let mesh = Engine::builder()
        .model("hypernet20")
        .seed(0xB17)
        .mesh(2, 2)
        .precision(Precision::F16)
        .build()
        .unwrap();
    let input = random_input(functional.input_len(), 99);
    let a = functional.infer(&input).unwrap();
    let b = mesh.infer(&input).unwrap();
    assert_eq!(a, b, "registry-resolved model diverged across backends");
}

#[test]
fn auto_mesh_composes_with_model_specs() {
    // The paper's 10×5 mesh for ResNet-34 @ 2048×1024, reached purely
    // through a spec string.
    let engine = Engine::builder()
        .model("resnet34@1024x2048")
        .auto_mesh()
        .build()
        .unwrap();
    let rep = engine.report();
    assert_eq!((rep.plan.rows, rep.plan.cols), (5, 10));
}

//! Parallel-execution determinism through the engine façade: the
//! `threads` knob must never change a bit of output or a single traffic
//! counter — each output pixel's FP16 rounding sequence runs entirely
//! inside one worker, workers write disjoint regions, and the per-worker
//! counters are exact partitions reduced in a fixed order.

use hyperdrive::engine::{Engine, EngineError, Precision};
use hyperdrive::util::SplitMix64;

fn random_input(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..len).map(|_| rng.next_sym()).collect()
}

#[test]
fn functional_outputs_invariant_across_thread_counts() {
    let build = |threads: usize| {
        Engine::builder()
            .model("hypernet20")
            .seed(0xD17)
            .precision(Precision::F16)
            .threads(threads)
            .build()
            .unwrap()
    };
    let input = random_input(16 * 32 * 32, 3);
    let want = build(1).infer(&input).unwrap();
    // 7 is the awkward case: it divides none of hypernet20's channel
    // counts, so the balanced split hands out unequal (±1) ranges.
    for threads in [2usize, 3, 7, 8] {
        let got = build(threads).infer(&input).unwrap();
        assert_eq!(got, want, "functional threads={threads} changed bits");
    }
}

#[test]
fn mesh_outputs_and_stats_invariant_across_thread_counts() {
    let build = |threads: usize| {
        Engine::builder()
            .model("hypernet20")
            .seed(0xD17)
            .mesh(2, 2)
            .precision(Precision::F16)
            .threads(threads)
            .build()
            .unwrap()
    };
    let input = random_input(16 * 32 * 32, 4);
    let base = build(1);
    let want = base.infer(&input).unwrap();
    let want_stats = base.mesh_stats().expect("stats recorded");
    assert!(want_stats.access.accumulates > 0, "kernel counters missing");
    for threads in [2usize, 5] {
        let engine = build(threads);
        let got = engine.infer(&input).unwrap();
        assert_eq!(got, want, "mesh threads={threads} changed bits");
        let stats = engine.mesh_stats().expect("stats recorded");
        assert_eq!(
            stats, want_stats,
            "mesh threads={threads} changed MeshStats/AccessCounts"
        );
    }
}

#[test]
fn default_threads_is_available_parallelism_and_matches_one_thread() {
    // No .threads(..): the builder resolves available_parallelism; the
    // result must still equal the single-thread reference bits.
    let default = Engine::builder()
        .model("hypernet20")
        .seed(0xAA)
        .precision(Precision::F16)
        .build()
        .unwrap();
    let single = Engine::builder()
        .model("hypernet20")
        .seed(0xAA)
        .precision(Precision::F16)
        .threads(1)
        .build()
        .unwrap();
    let input = random_input(default.input_len(), 5);
    assert_eq!(default.infer(&input).unwrap(), single.infer(&input).unwrap());
}

#[test]
fn zero_threads_is_a_builder_error() {
    let err = Engine::builder()
        .model("hypernet20")
        .threads(0)
        .build()
        .unwrap_err();
    assert!(matches!(err, EngineError::Builder(_)), "{err}");
    assert!(err.to_string().contains("threads"), "{err}");
}

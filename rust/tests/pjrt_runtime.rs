//! Integration tests over the PJRT runtime: the full AOT → load →
//! execute path, cross-checked against the JAX golden files and the
//! Rust functional simulator. Requires `make artifacts` and the `pjrt`
//! cargo feature (vendored xla-rs; see DESIGN.md §Substitutions).
#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use hyperdrive::network::TensorRef;
use hyperdrive::runtime::InferenceEngine;
use hyperdrive::simulator::{self, FeatureMap, Precision};
use hyperdrive::testkit::assert_allclose;

fn artifacts_dir() -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        dir.join("manifest.tsv").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    dir
}

fn engine() -> InferenceEngine {
    InferenceEngine::load(artifacts_dir()).expect("engine load")
}

#[test]
fn engine_loads_all_artifacts() {
    let e = engine();
    assert_eq!(e.runtime.loaded(), e.manifest.artifacts.len());
    assert!(e.runtime.has("head"));
    // Memory plan realizes the WCL exactly (2 × 16·32·32 words).
    assert_eq!(e.memory_plan.peak_words, 2 * 16 * 32 * 32);
}

#[test]
fn e2e_logits_match_jax_golden() {
    // The headline cross-layer check: Rust+PJRT inference must
    // reproduce the JAX/Pallas golden logits on the same input.
    let e = engine();
    let input = e.manifest.golden("e2e_input.bin").unwrap();
    let logits = e.infer(&input).unwrap();
    let golden = e.manifest.golden("e2e_golden.bin").unwrap();
    assert_eq!(logits.len(), golden.len());
    assert_allclose(&logits, &golden, 1e-4, 1e-4).unwrap();
}

#[test]
fn final_fm_matches_jax_golden() {
    let e = engine();
    let input = e.manifest.golden("e2e_input.bin").unwrap();
    let (fms, _) = e.infer_trace(&input).unwrap();
    let golden = e.manifest.golden("e2e_final_fm.bin").unwrap();
    assert_allclose(fms.last().unwrap(), &golden, 1e-4, 1e-4).unwrap();
}

#[test]
fn functional_simulator_matches_pjrt_per_layer() {
    // The Rust chip simulator (f32 datapath) and the XLA-compiled Pallas
    // kernel must agree layer by layer on the real network.
    let e = engine();
    let net = &e.manifest.network;
    let input_vec = e.manifest.golden("e2e_input.bin").unwrap();
    let (fms, _) = e.infer_trace(&input_vec).unwrap();

    let input = FeatureMap::from_vec(net.in_ch, net.in_h, net.in_w, input_vec);
    let mut sim_fms: Vec<FeatureMap> = Vec::new();
    for (i, s) in net.steps.iter().enumerate() {
        let l = &s.layer;
        let src = match s.src {
            TensorRef::Input => &input,
            TensorRef::Step(j) => &sim_fms[j],
        };
        let byp = s.bypass.map(|b| match b {
            TensorRef::Input => input.clone(),
            TensorRef::Step(j) => sim_fms[j].clone(),
        });
        let w = e.manifest.blob(&l.name, "w").unwrap();
        let stream = hyperdrive::bwn::pack_weights(l, w, 16);
        let params = simulator::chip::LayerParams {
            layer: l,
            stream: &stream,
            gamma: e.manifest.blob(&l.name, "gamma").unwrap(),
            beta: e.manifest.blob(&l.name, "beta").unwrap(),
        };
        let (out, _) =
            simulator::run_layer(&params, src, byp.as_ref(), Precision::F32, (7, 7));
        assert_allclose(&out.data, &fms[i], 2e-4, 2e-4)
            .unwrap_or_else(|m| panic!("layer {} ({}): {m}", i, l.name));
        sim_fms.push(out);
    }
}

#[test]
fn fp16_datapath_stays_close_to_f32_reference() {
    // The chip's FP16 rounding must not derail the network: logits from
    // the FP16 functional simulator stay close to the PJRT f32 result.
    let e = engine();
    let net = &e.manifest.network;
    let input_vec = e.manifest.golden("e2e_input.bin").unwrap();
    let (fms, _) = e.infer_trace(&input_vec).unwrap();

    let input = FeatureMap::from_vec(net.in_ch, net.in_h, net.in_w, input_vec);
    let mut sim_fms: Vec<FeatureMap> = Vec::new();
    for s in &net.steps {
        let l = &s.layer;
        let src = match s.src {
            TensorRef::Input => &input,
            TensorRef::Step(j) => &sim_fms[j],
        };
        let byp = s.bypass.map(|b| match b {
            TensorRef::Input => input.clone(),
            TensorRef::Step(j) => sim_fms[j].clone(),
        });
        let w = e.manifest.blob(&l.name, "w").unwrap();
        let stream = hyperdrive::bwn::pack_weights(l, w, 16);
        let params = simulator::chip::LayerParams {
            layer: l,
            stream: &stream,
            gamma: e.manifest.blob(&l.name, "gamma").unwrap(),
            beta: e.manifest.blob(&l.name, "beta").unwrap(),
        };
        let (out, _) = simulator::run_layer(&params, src, byp.as_ref(), Precision::F16, (7, 7));
        sim_fms.push(out);
    }
    let last = sim_fms.last().unwrap();
    assert_allclose(&last.data, fms.last().unwrap(), 0.05, 0.05)
        .expect("FP16 vs f32 divergence too large");
}

#[test]
fn runtime_error_paths_are_clean() {
    use hyperdrive::runtime::Runtime;
    let mut rt = Runtime::cpu().unwrap();
    // Missing artifact file.
    let err = rt
        .load_artifact("nope", std::path::Path::new("/nonexistent/x.hlo.txt"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("nonexistent"), "{err}");
    // Executing an unloaded artifact.
    let err = rt.execute("ghost", &[]).unwrap_err().to_string();
    assert!(err.contains("not loaded"), "{err}");
    // Loading a valid artifact but executing with wrong shapes must
    // error (not crash).
    let dir = artifacts_dir();
    rt.load_artifact(
        "head",
        &dir.join("head.hlo.txt"),
    )
    .unwrap();
    let bad = vec![0f32; 3];
    assert!(rt.execute("head", &[(&bad, &[3])]).is_err());
}

#[test]
fn manifest_blob_errors_are_contextual() {
    let e = engine();
    let err = e.manifest.blob("s1b0c1", "nonsense").unwrap_err().to_string();
    assert!(err.contains("nonsense"), "{err}");
    let err = e.manifest.golden("missing.bin").unwrap_err().to_string();
    assert!(err.contains("missing.bin"), "{err}");
}

#[test]
fn serve_batch_reports_latency() {
    use hyperdrive::engine::{Engine, ServeOptions};
    let engine = Engine::builder().artifacts(artifacts_dir()).build().unwrap();
    let input = engine.golden("e2e_input.bin").unwrap();
    let inputs: Vec<Vec<f32>> = (0..4).map(|_| input.clone()).collect();
    let opts = ServeOptions {
        workers: 2,
        ..ServeOptions::default()
    };
    let (outs, stats) = engine.serve(&inputs, &opts).unwrap().outputs().unwrap();
    assert_eq!(outs.len(), 4);
    assert!(stats.p50_ms > 0.0 && stats.p99_ms >= stats.p50_ms);
    assert!(stats.ops_per_s > 0.0);
    // Deterministic engine: identical inputs → identical outputs, and
    // the concurrent pool must match a sequential pass bit-for-bit.
    assert_eq!(outs[0], outs[3]);
    let seq = ServeOptions {
        workers: 1,
        ..ServeOptions::default()
    };
    let (seq_outs, _) = engine.serve(&inputs, &seq).unwrap().outputs().unwrap();
    assert_eq!(outs, seq_outs);
}

//! Integration tests of the multi-model `InferenceService`: builder
//! validation, the interleaved multi-model soak (bit-exact against
//! direct `Engine::infer`), per-request failure isolation, hot
//! add/remove and graceful shutdown.

use hyperdrive::engine::{
    Engine, EngineError, InferRequest, InferenceService, ModelConfig, ServeError,
};
use hyperdrive::util::SplitMix64;

fn random_input(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..len).map(|_| rng.next_sym()).collect()
}

#[test]
fn builder_validates_its_inputs() {
    // Zero knobs are typed errors, not silent clamps (like
    // EngineBuilder::threads).
    let err = InferenceService::builder()
        .model_spec("hypernet20")
        .workers(0)
        .build()
        .unwrap_err();
    assert!(matches!(err, EngineError::Builder(_)), "{err}");
    assert!(err.to_string().contains("workers"), "{err}");

    let err = InferenceService::builder()
        .model_spec("hypernet20")
        .queue_depth(0)
        .build()
        .unwrap_err();
    assert!(matches!(err, EngineError::Builder(_)), "{err}");
    assert!(err.to_string().contains("queue_depth"), "{err}");

    // The per-model depth override is validated too.
    let err = InferenceService::builder()
        .model("m", ModelConfig::new("hypernet20").queue_depth(0))
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("queue_depth(0)"), "{err}");

    // No models, duplicate names, unknown specs.
    let err = InferenceService::builder().build().unwrap_err();
    assert!(err.to_string().contains("at least one"), "{err}");
    let err = InferenceService::builder()
        .model_spec("hypernet20")
        .model_spec("hypernet20")
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("twice"), "{err}");
    let err = InferenceService::builder()
        .model_spec("resnet99")
        .build()
        .unwrap_err();
    assert!(matches!(err, EngineError::Model(_)), "{err}");
}

#[test]
fn multi_model_soak_is_bit_exact_and_metrics_add_up() {
    const MODELS: [&str; 2] = ["hypernet20", "resnet18@32x32"];
    const REQUESTS: usize = 64;
    let service = InferenceService::builder()
        .model_spec(MODELS[0])
        .model_spec(MODELS[1])
        .workers(4)
        .queue_depth(8)
        .build()
        .unwrap();
    // Reference engines resolved from the same specs: the service's
    // responses must be bit-identical to direct Engine::infer.
    let direct: Vec<Engine> = MODELS
        .iter()
        .map(|m| Engine::builder().model(*m).build().unwrap())
        .collect();

    let mut tickets = Vec::with_capacity(REQUESTS);
    let mut expected = Vec::with_capacity(REQUESTS);
    for i in 0..REQUESTS {
        let which = i % MODELS.len();
        let input = random_input(direct[which].input_len(), 1000 + i as u64);
        expected.push(direct[which].infer(&input).unwrap());
        tickets.push(
            service
                .submit(InferRequest {
                    model: MODELS[which].into(),
                    input: input.into(),
                    id: i as u64,
                    deadline_ms: None,
                })
                .unwrap(),
        );
    }
    for (i, ticket) in tickets.into_iter().enumerate() {
        assert_eq!(ticket.id(), i as u64);
        let resp = ticket.wait().unwrap();
        assert_eq!(resp.id, i as u64);
        assert_eq!(resp.model, MODELS[i % MODELS.len()]);
        assert_eq!(
            resp.output,
            expected[i],
            "request {i} diverged from direct Engine::infer"
        );
        assert!(resp.latency_ms > 0.0);
    }

    // Shutdown drains (here: everything already waited) and the final
    // metrics must account for every request.
    let metrics = service.shutdown();
    assert_eq!(metrics.total_submitted(), REQUESTS as u64);
    assert_eq!(metrics.total_completed(), REQUESTS as u64);
    assert_eq!(metrics.total_failed(), 0);
    assert_eq!(metrics.workers, 4);
    assert_eq!(metrics.per_model.len(), 2);
    for (pm, eng) in metrics.per_model.iter().zip(&direct) {
        assert_eq!(pm.submitted, (REQUESTS / 2) as u64, "{}", pm.model);
        assert_eq!(pm.completed, (REQUESTS / 2) as u64);
        assert_eq!((pm.queued, pm.in_flight), (0, 0));
        assert!(pm.p99_ms >= pm.p50_ms && pm.p50_ms > 0.0, "{pm:?}");
        assert!(pm.mean_ms > 0.0 && pm.req_per_s > 0.0 && pm.ops_per_s > 0.0);
        // Every hosted model reports its resident packed-weight
        // footprint — the same analytic figure the direct engine gives.
        assert_eq!(pm.weight_bytes, eng.resident_weight_bytes(), "{}", pm.model);
        assert!(pm.weight_bytes > 0, "{}", pm.model);
    }
    assert_eq!(
        metrics.total_weight_bytes(),
        direct.iter().map(|e| e.resident_weight_bytes()).sum::<u64>()
    );
    assert!(metrics.render_table().contains("wt KiB"));
    // The snapshot converts to single-model ServeStats for the report
    // path, consistent with the per-model row.
    let stats = metrics.serve_stats(MODELS[0]).unwrap();
    let row = metrics.model(MODELS[0]).unwrap();
    assert_eq!(stats.requests, 32);
    assert_eq!(stats.completed, 32);
    assert_eq!(stats.workers, 4);
    assert_eq!(stats.p50_ms, row.p50_ms);
    assert_eq!(stats.p99_ms, row.p99_ms);
    assert_eq!(stats.ops_per_s, row.ops_per_s);
}

#[test]
fn failing_model_does_not_lose_other_requests() {
    // `flaky` builds fine (the analytic mesh plan accepts 3×3) but
    // every inference fails: 32×32 FMs do not divide over 3×3 chips.
    // Its failures must be scoped to its own requests.
    let service = InferenceService::builder()
        .model_spec("hypernet20")
        .model("flaky", ModelConfig::new("hypernet20").mesh(3, 3))
        .workers(4)
        .build()
        .unwrap();
    let direct = Engine::builder().model("hypernet20").build().unwrap();

    let mut tickets = Vec::new();
    for i in 0..16u64 {
        let model = if i % 2 == 0 { "hypernet20" } else { "flaky" };
        tickets.push(
            service
                .submit(InferRequest {
                    model: model.into(),
                    input: random_input(direct.input_len(), 50 + i).into(),
                    id: i,
                    deadline_ms: None,
                })
                .unwrap(),
        );
    }
    for (i, ticket) in tickets.into_iter().enumerate() {
        let result = ticket.wait();
        if i % 2 == 0 {
            let expected = direct
                .infer(&random_input(direct.input_len(), 50 + i as u64))
                .unwrap();
            assert_eq!(result.unwrap().output, expected, "good request {i} lost");
        } else {
            let err = result.unwrap_err();
            assert!(matches!(err, ServeError::Failed { .. }), "{err}");
            assert!(err.to_string().contains("flaky"), "{err}");
        }
    }
    let metrics = service.shutdown();
    let good = metrics.model("hypernet20").unwrap();
    let flaky = metrics.model("flaky").unwrap();
    assert_eq!((good.completed, good.failed), (8, 0));
    assert_eq!((flaky.completed, flaky.failed), (0, 8));
}

#[test]
fn submit_errors_are_typed_and_scoped() {
    let service = InferenceService::builder()
        .model_spec("hypernet20")
        .workers(2)
        .build()
        .unwrap();
    let want = service.input_len("hypernet20").unwrap();

    match service
        .submit(InferRequest {
            model: "resnet34".into(),
            input: vec![0.0; want].into(),
            id: 0,
            deadline_ms: None,
        })
        .unwrap_err()
    {
        ServeError::UnknownModel { model, known } => {
            assert_eq!(model, "resnet34");
            assert_eq!(known, vec!["hypernet20".to_string()]);
        }
        other => panic!("expected UnknownModel, got {other}"),
    }
    match service
        .submit(InferRequest {
            model: "hypernet20".into(),
            input: vec![0.0; 7].into(),
            id: 0,
            deadline_ms: None,
        })
        .unwrap_err()
    {
        ServeError::BadInput { got, want: w, .. } => assert_eq!((got, w), (7, want)),
        other => panic!("expected BadInput, got {other}"),
    }
    // Neither rejection perturbed the metrics.
    assert_eq!(service.shutdown().total_submitted(), 0);
}

#[test]
fn hot_add_and_remove_models() {
    let service = InferenceService::builder()
        .model_spec("hypernet20")
        .workers(2)
        .build()
        .unwrap();
    assert_eq!(service.models(), vec!["hypernet20".to_string()]);

    // Unknown until added…
    let err = service.infer("tiny", vec![0.0; 16]).unwrap_err();
    assert!(matches!(err, ServeError::UnknownModel { .. }), "{err}");

    // …then hot-added and bit-exact against a direct engine.
    service
        .add_model("tiny", ModelConfig::new("resnet18@32x32"))
        .unwrap();
    assert_eq!(service.models().len(), 2);
    let direct = Engine::builder().model("resnet18@32x32").build().unwrap();
    let input = random_input(direct.input_len(), 99);
    assert_eq!(
        service.infer("tiny", input.clone()).unwrap(),
        direct.infer(&input).unwrap()
    );

    // Duplicate adds are typed errors.
    let err = service
        .add_model("hypernet20", ModelConfig::new("hypernet20"))
        .unwrap_err();
    assert!(matches!(err, EngineError::Builder(_)), "{err}");

    // Removal: new submissions get ModelRemoved; the survivor serves.
    service.remove_model("tiny").unwrap();
    let err = service.infer("tiny", input).unwrap_err();
    assert!(matches!(err, ServeError::ModelRemoved { .. }), "{err}");
    let hn_input = random_input(service.input_len("hypernet20").unwrap(), 7);
    assert!(service.infer("hypernet20", hn_input).is_ok());

    let metrics = service.shutdown();
    let tiny = metrics.model("tiny").unwrap();
    assert!(tiny.removed);
    assert_eq!(tiny.completed, 1);
}

#[test]
fn idle_shutdown_is_clean() {
    let service = InferenceService::builder()
        .model_spec("hypernet20")
        .model_spec("resnet18@32x32")
        .workers(3)
        .build()
        .unwrap();
    let metrics = service.shutdown();
    assert_eq!(metrics.total_submitted(), 0);
    assert_eq!(metrics.per_model.len(), 2);
    for pm in &metrics.per_model {
        assert_eq!(pm.p50_ms, 0.0);
        assert_eq!(pm.ops_per_s, 0.0);
    }
}

#[test]
fn engine_serve_wrapper_matches_the_service_path() {
    // Engine::serve is a compat wrapper over a single-model service:
    // same inputs through both APIs must give identical outputs, and
    // the stats must agree on the counts.
    let engine = Engine::builder().model("hypernet20").build().unwrap();
    let inputs: Vec<Vec<f32>> = (0..6)
        .map(|i| random_input(engine.input_len(), 300 + i))
        .collect();
    let outcome = engine
        .serve(&inputs, &hyperdrive::engine::ServeOptions::default())
        .unwrap();
    assert_eq!(outcome.stats.requests, 6);
    assert_eq!(outcome.stats.completed, 6);

    let service = InferenceService::builder()
        .model_spec("hypernet20")
        .workers(2)
        .build()
        .unwrap();
    for (i, input) in inputs.iter().enumerate() {
        let via_service = service.infer("hypernet20", input.clone()).unwrap();
        assert_eq!(
            outcome.results[i].as_ref().unwrap(),
            &via_service,
            "request {i}: wrapper and service disagree"
        );
    }
    let metrics = service.shutdown();
    assert_eq!(metrics.total_completed(), 6);
}

//! Streaming-video subsystem integration tests.
//!
//! 1. Property: `DirtyMap::propagate` is *exact* receptive-field
//!    reachability — compared against a brute-force per-pixel tap walk
//!    over random k/stride/upsample layers and random dirty patterns.
//! 2. Registry sweep: video mode (temporal dirty-tile reuse) is
//!    bit-identical to per-frame full recompute for every registry
//!    entry, on the functional backend and the 2×2 mesh, at each
//!    entry's sweep precision.
//! 3. Savings: a 5%-delta stream must save at least
//!    `1 − dirty-fraction − ε` of the MACs on every incremental frame.
//! 4. Placement: two models on disjoint sub-meshes of one pool serve
//!    concurrently with reconciling per-model metrics.
//! 5. Wire: the load generator's `--video` replay drives a loopback
//!    server with sequential clip frames.

use std::collections::HashMap;
use std::sync::Arc;

use hyperdrive::engine::{
    run_loadgen, Engine, InferRequest, InferenceService, LoadGenConfig, ModelConfig, Precision,
    RetryPolicy, WireServer,
};
use hyperdrive::model::NetworkRegistry;
use hyperdrive::network::ConvLayer;
use hyperdrive::util::SplitMix64;
use hyperdrive::video::{DirtyMap, MeshPlacement, SynthVideo};

/// Brute-force receptive-field reachability: an output tile is dirty
/// iff any of its pixels has any in-bounds k×k tap (same padding,
/// `-(k/2)`-anchored like the datapath) landing in a dirty input tile.
fn brute_force_propagate(m: &DirtyMap, l: &ConvLayer) -> DirtyMap {
    let mut out = DirtyMap::clean(l.h_out(), l.w_out(), m.tile);
    let dlo = -((l.k / 2) as isize);
    for oy in 0..l.h_out() {
        for ox in 0..l.w_out() {
            let mut dirty = false;
            for dy in 0..l.k as isize {
                for dx in 0..l.k as isize {
                    let iy = (oy * l.stride) as isize + dlo + dy;
                    let ix = (ox * l.stride) as isize + dlo + dx;
                    if iy < 0 || ix < 0 || iy >= l.h as isize || ix >= l.w as isize {
                        continue; // zero padding never changes
                    }
                    dirty |= m.is_dirty_tile(iy as usize / m.tile, ix as usize / m.tile);
                }
            }
            if dirty {
                out.mark_tile(oy / m.tile, ox / m.tile);
            }
        }
    }
    out
}

fn brute_force_upsample(m: &DirtyMap) -> DirtyMap {
    let mut out = DirtyMap::clean(m.h * 2, m.w * 2, m.tile);
    for y in 0..m.h * 2 {
        for x in 0..m.w * 2 {
            if m.is_dirty_tile((y / 2) / m.tile, (x / 2) / m.tile) {
                out.mark_tile(y / m.tile, x / m.tile);
            }
        }
    }
    out
}

fn random_map(h: usize, w: usize, tile: usize, rng: &mut SplitMix64) -> DirtyMap {
    let mut m = DirtyMap::clean(h, w, tile);
    let (th, tw) = m.grid();
    for ty in 0..th {
        for tx in 0..tw {
            if rng.next_below(10) < 3 {
                m.mark_tile(ty, tx);
            }
        }
    }
    m
}

#[test]
fn propagate_matches_brute_force_reachability() {
    let mut rng = SplitMix64::new(0xd1127);
    for case in 0..200 {
        let h = 4 + rng.next_below(13); // 4..=16
        let w = 4 + rng.next_below(13);
        let tile = 1 + rng.next_below(4); // 1..=4
        let k = if rng.next_u64() & 1 == 0 { 1 } else { 3 };
        let stride = if rng.next_u64() & 1 == 0 { 1 } else { 2 };
        let l = ConvLayer::new("p", 1, 1, h, w, k, stride);
        let m = random_map(h, w, tile, &mut rng);
        let (got, want) = (m.propagate(&l), brute_force_propagate(&m, &l));
        assert_eq!(
            got, want,
            "case {case}: {h}x{w} tile {tile} k{k} s{stride} diverged"
        );
    }
}

#[test]
fn propagate_chains_match_brute_force_through_a_random_network() {
    // Walk random layer stacks (conv / conv / upsample …) propagating
    // both ways; the maps must agree at every depth, not just one hop.
    let mut rng = SplitMix64::new(0xc4a1);
    for case in 0..40 {
        let (mut h, mut w) = (
            8 + 2 * rng.next_below(5), // even, 8..=16
            8 + 2 * rng.next_below(5),
        );
        let tile = 1 + rng.next_below(3);
        let mut exact = random_map(h, w, tile, &mut rng);
        let mut brute = exact.clone();
        for step in 0..4 {
            if h >= 4 && w >= 4 && rng.next_below(4) == 0 {
                exact = exact.upsample();
                brute = brute_force_upsample(&brute);
                h *= 2;
                w *= 2;
            } else {
                let k = if rng.next_u64() & 1 == 0 { 1 } else { 3 };
                let stride = if rng.next_u64() & 1 == 0 || h % 2 != 0 || w % 2 != 0 {
                    1
                } else {
                    2
                };
                let l = ConvLayer::new("c", 1, 1, h, w, k, stride);
                exact = exact.propagate(&l);
                brute = brute_force_propagate(&brute, &l);
                h = l.h_out();
                w = l.w_out();
            }
            assert_eq!(exact, brute, "case {case} step {step} ({h}x{w})");
        }
    }
}

/// The zoo sweep table: smallest resolution whose tensors all divide
/// over 2×2 chips, same as `tests/zoo_mesh_sweep.rs`.
fn sweep_spec() -> HashMap<&'static str, (&'static str, Precision)> {
    [
        ("resnet18", ("resnet18@64x64", Precision::F32)),
        ("resnet34", ("resnet34@64x64", Precision::F32)),
        ("resnet50", ("resnet50@64x64", Precision::F32)),
        ("resnet152", ("resnet152@64x64", Precision::F32)),
        ("shufflenet", ("shufflenet@64x64", Precision::F32)),
        ("yolov3", ("yolov3@64x64", Precision::F16)),
        ("tinyyolo", ("tinyyolo@64x64", Precision::F32)),
        ("hypernet20", ("hypernet20", Precision::F16)),
    ]
    .into_iter()
    .collect()
}

#[test]
fn registry_video_sweep_is_bit_exact_on_both_backends() {
    let sweep = sweep_spec();
    for name in NetworkRegistry::builtin().names() {
        let (spec, prec) = *sweep
            .get(name)
            .unwrap_or_else(|| panic!("registry entry `{name}` has no sweep spec — add one"));
        let functional = Engine::builder()
            .model(spec)
            .seed(0x5eed)
            .precision(prec)
            .threads(2)
            .build()
            .unwrap_or_else(|e| panic!("{spec} functional build: {e}"));
        let mesh = Engine::builder()
            .model(spec)
            .seed(0x5eed)
            .mesh(2, 2)
            .precision(prec)
            .build()
            .unwrap_or_else(|e| panic!("{spec} mesh build: {e}"));
        let net = functional.network();
        let mut clip = SynthVideo::new(net.in_ch, net.in_h, net.in_w, 0.05, 42);
        let mut fses = functional.video_session(8, 0.0).expect("functional session");
        let mut mses = mesh.video_session(8, 0.0).expect("mesh session");
        for frame_no in 0..3 {
            let frame = clip.next_flat();
            let golden = functional
                .infer(&frame)
                .unwrap_or_else(|e| panic!("{spec} full recompute: {e}"));
            let (fv, fstats) = fses.process_flat(&frame).expect("functional video frame");
            let (mv, mstats) = mses.process_flat(&frame).expect("mesh video frame");
            assert_eq!(
                fv, golden,
                "{spec} ({prec:?}) functional video diverged at frame {frame_no}"
            );
            assert_eq!(
                mv, golden,
                "{spec} ({prec:?}) mesh video diverged at frame {frame_no}"
            );
            if frame_no > 0 {
                assert!(
                    fstats.access.saved_macs > 0,
                    "{spec} functional frame {frame_no} saved nothing"
                );
                assert!(
                    mstats.access.saved_macs > 0,
                    "{spec} mesh frame {frame_no} saved nothing"
                );
            }
        }
    }
}

#[test]
fn five_percent_delta_saves_at_least_the_clean_fraction() {
    let engine = Engine::builder()
        .model("hypernet20")
        .seed(0x5eed)
        .build()
        .expect("engine build");
    let net = engine.network();
    let mut clip = SynthVideo::new(net.in_ch, net.in_h, net.in_w, 0.05, 7);
    let mut session = engine.video_session(8, 0.0).expect("video session");
    for frame_no in 0..4 {
        let frame = clip.next_flat();
        let (_, stats) = session.process_flat(&frame).expect("video frame");
        if frame_no == 0 {
            assert_eq!(stats.mac_dirty_fraction, 1.0);
            continue;
        }
        // Acceptance bound: saved ≥ 1 − dirty − ε. The counters are
        // analytic, so the identity in fact holds to rounding.
        let saved = stats.saved_mac_ratio();
        let bound = 1.0 - stats.mac_dirty_fraction - 0.01;
        assert!(
            saved >= bound,
            "frame {frame_no}: saved {saved:.4} < 1 - dirty {:.4} - eps",
            stats.mac_dirty_fraction
        );
        assert!(
            (saved - (1.0 - stats.mac_dirty_fraction)).abs() < 1e-6,
            "frame {frame_no}: saved {saved:.6} != 1 - dirty identity"
        );
        assert_eq!(
            stats.access.accumulates + stats.access.saved_macs,
            stats.total_macs,
            "frame {frame_no}: done + saved != total MACs"
        );
        assert!(
            stats.mac_dirty_fraction < 0.6,
            "frame {frame_no}: a 5% input delta dirtied {:.2} of the MACs",
            stats.mac_dirty_fraction
        );
    }
}

#[test]
fn disjoint_sub_meshes_serve_two_models_from_one_pool() {
    // Carve a 4×4 pool for two models; both sub-meshes must be
    // disjoint rectangles, and the shared service must serve each
    // model's frames on its own slice with reconciling metrics.
    let specs = ["hypernet20", "hypernet20@32x32"];
    let mut placement = MeshPlacement::new(4, 4);
    let mut builder = InferenceService::builder().workers(2);
    for spec in specs {
        let sm = placement.place(spec, 4).expect("pool has room");
        assert_eq!((sm.rows, sm.cols), (2, 2));
        builder = builder.model(spec, ModelConfig::new(spec).sub_mesh(sm).seed(0x5eed));
    }
    // First-fit placements of equal shape can never overlap.
    let placed: Vec<_> = placement.placements().collect();
    assert_eq!(placed.len(), 2);
    let (a, b) = (placed[0].1, placed[1].1);
    let disjoint = a.row0 + a.rows <= b.row0
        || b.row0 + b.rows <= a.row0
        || a.col0 + a.cols <= b.col0
        || b.col0 + b.cols <= a.col0;
    assert!(disjoint, "sub-meshes overlap: {a} vs {b}");
    assert_eq!(placement.free_chips(), 8);

    let service = builder.build().expect("service build");
    // The reference engine runs the same spec + seed without a service
    // in the way; sub-mesh serving must agree bit for bit.
    let reference = Engine::builder()
        .model(specs[0])
        .seed(0x5eed)
        .mesh(2, 2)
        .build()
        .expect("reference build");
    let frames = 3;
    let mut tickets = Vec::new();
    let mut clips: Vec<SynthVideo> = specs
        .iter()
        .map(|s| {
            let len = service.input_len(s).expect("hosted model");
            SynthVideo::flat(len, 0.05, 99)
        })
        .collect();
    let mut first_inputs = Vec::new();
    for f in 0..frames {
        for (mi, spec) in specs.iter().enumerate() {
            let input: Arc<[f32]> = clips[mi].next_flat().into();
            if mi == 0 && f == 0 {
                first_inputs.push(input.clone());
            }
            tickets.push(
                service
                    .submit(InferRequest {
                        model: spec.to_string(),
                        input,
                        id: (f * specs.len() + mi) as u64,
                        deadline_ms: None,
                    })
                    .expect("admission"),
            );
        }
    }
    let mut outputs = Vec::new();
    for t in tickets {
        outputs.push(t.wait().expect("inference"));
    }
    let want = reference
        .infer(&first_inputs[0])
        .expect("reference inference");
    let got = outputs
        .iter()
        .find(|r| r.id == 0)
        .expect("response for id 0");
    assert_eq!(got.output, want, "sub-mesh serving diverged from reference");
    let metrics = service.shutdown();
    for spec in specs {
        let m = metrics.model(spec).expect("per-model metrics row");
        assert_eq!(
            (m.submitted, m.completed, m.failed),
            (frames as u64, frames as u64, 0),
            "{spec} metrics do not reconcile"
        );
    }
    assert_eq!(metrics.total_completed(), (frames * specs.len()) as u64);
}

#[test]
fn loadgen_video_replay_drives_a_loopback_server() {
    let service = Arc::new(
        InferenceService::builder()
            .model_spec("hypernet20")
            .workers(2)
            .queue_depth(8)
            .build()
            .expect("service build"),
    );
    let server = WireServer::start(service.clone(), "127.0.0.1:0").expect("bind loopback");
    let report = run_loadgen(&LoadGenConfig {
        addr: server.local_addr().to_string(),
        connections: 2,
        in_flight: 2,
        requests: 12,
        models: vec!["hypernet20".to_string()],
        seed: 7,
        retry: RetryPolicy::default(),
        deadline_ms: None,
        chaos: None,
        video: Some(4),
        video_delta: 0.1,
    })
    .expect("loadgen run");
    assert_eq!(report.sent, 12);
    assert_eq!(report.ok, 12);
    assert_eq!(report.failed, 0);
    assert_eq!(report.transport_errors, 0);
    let stats = server.shutdown();
    assert_eq!(stats.infer_rx, 12);
    let metrics = Arc::try_unwrap(service)
        .unwrap_or_else(|_| panic!("server joined; last Arc"))
        .shutdown();
    assert_eq!(metrics.total_completed(), 12);
}

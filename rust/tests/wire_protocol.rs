//! Wire-protocol contract tests: codec round-trips under random
//! payloads, typed errors on every malformed-input class, and loopback
//! TCP end-to-end runs asserting the wire path is bit-exact vs direct
//! `Engine::infer` with failure isolation per connection.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use hyperdrive::engine::wire::frame::{
    ErrorCode, Frame, WireError, CONNECTION_ID, MAX_BODY, WIRE_VERSION,
};
use hyperdrive::engine::{
    run_loadgen, AdmissionPolicy, Engine, InferenceService, LoadGenConfig, RetryPolicy,
    WireClient, WireServer,
};
use hyperdrive::faults::{FaultKind, FaultPlan, Trigger};
use hyperdrive::util::SplitMix64;

const MODELS: [&str; 2] = ["hypernet20", "resnet18@32x32"];

fn round_trip(frame: &Frame) -> Frame {
    let bytes = frame.encode();
    let mut cursor = &bytes[..];
    Frame::read_from(&mut cursor).expect("round trip decodes")
}

#[test]
fn codec_round_trips_every_frame_kind() {
    let mut rng = SplitMix64::new(0xC0DEC);
    // Payload sizes cover the edges: empty, one, and a large tensor.
    for &n in &[0usize, 1, 3, 257, 65_536] {
        let payload: Vec<f32> = (0..n).map(|_| rng.next_sym()).collect();
        let frames = [
            Frame::Hello {
                version: WIRE_VERSION,
                models: vec![("hypernet20".into(), 3072), ("".into(), 0)],
            },
            Frame::Infer {
                id: rng.next_u64(),
                model: "resnet18@32x32".into(),
                input: payload.clone().into(),
                deadline_ms: 250,
                attempt: 2,
            },
            Frame::Result {
                id: rng.next_u64(),
                latency_ms: 1.25,
                output: payload.clone(),
            },
            Frame::Error {
                id: CONNECTION_ID,
                code: ErrorCode::QueueFull.as_u8(),
                message: "model `x`: queue full (8 pending)".into(),
            },
            Frame::MetricsRequest,
            Frame::MetricsReply {
                table: "model  sub  ok\n".into(),
            },
            Frame::Goodbye,
        ];
        for frame in &frames {
            assert_eq!(&round_trip(frame), frame, "n = {n}");
        }
    }
}

#[test]
fn codec_round_trips_random_infer_payloads() {
    let mut rng = SplitMix64::new(7);
    for _ in 0..200 {
        let n = rng.next_below(4096);
        let input: Vec<f32> = (0..n).map(|_| rng.next_gauss()).collect();
        let frame = Frame::Infer {
            id: rng.next_u64(),
            model: format!("m{}", rng.next_below(100)),
            input: input.into(),
            deadline_ms: rng.next_u64() % 10_000,
            attempt: (rng.next_u64() % 4) as u8,
        };
        assert_eq!(round_trip(&frame), frame);
    }
}

#[test]
fn truncated_streams_are_typed_errors() {
    let bytes = Frame::Goodbye.encode();
    // Cut inside the length prefix.
    let mut cursor = &bytes[..2];
    assert!(matches!(
        Frame::read_from(&mut cursor),
        Err(WireError::Truncated { expected: 4, got: 2 })
    ));
    // Cut inside the body of a bigger frame.
    let bytes = Frame::Infer {
        id: 1,
        model: "m".into(),
        input: vec![1.0, 2.0, 3.0].into(),
        deadline_ms: 0,
        attempt: 0,
    }
    .encode();
    for cut in 5..bytes.len() {
        let mut cursor = &bytes[..cut];
        assert!(
            matches!(Frame::read_from(&mut cursor), Err(WireError::Truncated { .. })),
            "cut at {cut}"
        );
    }
    // A clean EOF between frames is Closed, not Truncated.
    let mut cursor: &[u8] = &[];
    assert!(matches!(Frame::read_from(&mut cursor), Err(WireError::Closed)));
}

#[test]
fn hostile_prefixes_and_bodies_are_typed_errors() {
    // Oversized length prefix: refused before any allocation.
    let mut bytes = ((MAX_BODY + 1) as u32).to_le_bytes().to_vec();
    bytes.extend_from_slice(&[0u8; 16]);
    let mut cursor = &bytes[..];
    assert!(matches!(
        Frame::read_from(&mut cursor),
        Err(WireError::Oversized { .. })
    ));
    // Zero-length body.
    let mut cursor: &[u8] = &0u32.to_le_bytes()[..];
    assert!(matches!(
        Frame::read_from(&mut cursor),
        Err(WireError::Malformed(_))
    ));
    // Unknown kind byte.
    assert!(matches!(Frame::decode(&[99]), Err(WireError::UnknownKind(99))));
    // Wrong hello magic.
    let mut body = vec![1u8];
    body.extend_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
    body.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    body.extend_from_slice(&0u16.to_le_bytes());
    assert!(matches!(Frame::decode(&body), Err(WireError::BadMagic(0xDEAD_BEEF))));
    // Trailing bytes after a valid frame.
    let mut bytes = Frame::Goodbye.encode();
    bytes[0] += 1; // length prefix now claims one extra body byte
    bytes.push(0);
    let mut cursor = &bytes[..];
    assert!(matches!(
        Frame::read_from(&mut cursor),
        Err(WireError::Malformed(_))
    ));
    // A count field that runs past the body.
    let mut body = vec![2u8]; // Infer
    body.extend_from_slice(&1u64.to_le_bytes());
    body.extend_from_slice(&1u16.to_le_bytes());
    body.push(b'm');
    body.extend_from_slice(&1000u32.to_le_bytes()); // claims 1000 f32s, has 0
    assert!(matches!(Frame::decode(&body), Err(WireError::Malformed(_))));
    // Random garbage bodies never panic; they decode or fail typed.
    let mut rng = SplitMix64::new(0xBAD);
    for _ in 0..500 {
        let n = 1 + rng.next_below(64);
        let body: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let _ = Frame::decode(&body);
    }
}

fn start_service() -> Arc<InferenceService> {
    let mut builder = InferenceService::builder().workers(4).queue_depth(64);
    for model in MODELS {
        builder = builder.model_spec(model);
    }
    Arc::new(builder.build().expect("service build"))
}

#[test]
fn loopback_soak_is_bit_exact_vs_direct_infer() {
    // Reference engines built exactly like the service's models: the
    // synthetic parameters are seed-deterministic, so the TCP path
    // must reproduce Engine::infer bit-for-bit.
    let references: Vec<Engine> = MODELS
        .iter()
        .map(|m| Engine::builder().model(*m).build().expect("engine build"))
        .collect();
    let service = start_service();
    let server = WireServer::start(service.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr().to_string();

    let handles: Vec<_> = (0..4)
        .map(|c| {
            let addr = addr.clone();
            let inputs: Vec<Vec<f32>> = references
                .iter()
                .map(|e| {
                    let mut rng = SplitMix64::new(1000 + c);
                    (0..e.input_len()).map(|_| rng.next_sym()).collect()
                })
                .collect();
            let expected: Vec<Vec<f32>> = references
                .iter()
                .zip(&inputs)
                .map(|(e, x)| e.infer(x).expect("reference inference"))
                .collect();
            std::thread::spawn(move || {
                let mut client = WireClient::connect(&addr).expect("connect");
                assert_eq!(client.models().len(), MODELS.len());
                for round in 0..3 {
                    for ((model, input), want) in MODELS.iter().zip(&inputs).zip(&expected) {
                        let got = client.infer(model, input).expect("wire inference");
                        assert_eq!(&got, want, "conn {c} round {round} model {model}");
                    }
                }
                client.goodbye().expect("clean teardown");
            })
        })
        .collect();
    for h in handles {
        h.join().expect("soak connection");
    }

    let stats = server.shutdown();
    assert_eq!(stats.connections, 4);
    assert_eq!(stats.malformed, 0);
    assert_eq!(stats.infer_rx, 4 * 3 * MODELS.len() as u64);
    assert_eq!(stats.results_tx, stats.infer_rx);
    let metrics = Arc::try_unwrap(service).ok().expect("last Arc").shutdown();
    assert_eq!(metrics.total_completed(), stats.infer_rx);
    assert_eq!(metrics.total_failed(), 0);
}

#[test]
fn version_mismatch_is_refused_on_the_wire() {
    let service = start_service();
    let server = WireServer::start(service.clone(), "127.0.0.1:0").expect("bind");
    let mut stream = TcpStream::connect(server.local_addr()).expect("connect");
    let hello = Frame::Hello {
        version: WIRE_VERSION + 9,
        models: Vec::new(),
    };
    stream.write_all(&hello.encode()).expect("send hello");
    let mut reader = std::io::BufReader::new(stream.try_clone().expect("clone"));
    match Frame::read_from(&mut reader) {
        Ok(Frame::Error { id, code, message }) => {
            assert_eq!(id, CONNECTION_ID);
            assert_eq!(code, ErrorCode::VersionMismatch.as_u8());
            assert!(message.contains("version"), "{message}");
        }
        other => panic!("expected a version-mismatch Error frame, got {other:?}"),
    }
    // The server hangs up after refusing.
    let mut rest = Vec::new();
    let _ = reader.read_to_end(&mut rest);
    assert!(rest.is_empty());
    server.shutdown();
}

#[test]
fn bad_magic_and_non_hello_handshakes_are_refused() {
    let service = start_service();
    let server = WireServer::start(service.clone(), "127.0.0.1:0").expect("bind");
    // Garbage magic.
    let mut stream = TcpStream::connect(server.local_addr()).expect("connect");
    let mut body = vec![1u8];
    body.extend_from_slice(&0x1234_5678u32.to_le_bytes());
    body.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    body.extend_from_slice(&0u16.to_le_bytes());
    let mut bytes = (body.len() as u32).to_le_bytes().to_vec();
    bytes.extend_from_slice(&body);
    stream.write_all(&bytes).expect("send");
    let mut reader = std::io::BufReader::new(stream);
    match Frame::read_from(&mut reader) {
        Ok(Frame::Error { id, code, .. }) => {
            assert_eq!(id, CONNECTION_ID);
            assert_eq!(code, ErrorCode::Protocol.as_u8());
        }
        other => panic!("expected a protocol Error frame, got {other:?}"),
    }
    // First frame not Hello.
    let mut stream = TcpStream::connect(server.local_addr()).expect("connect");
    stream
        .write_all(&Frame::MetricsRequest.encode())
        .expect("send");
    let mut reader = std::io::BufReader::new(stream);
    match Frame::read_from(&mut reader) {
        Ok(Frame::Error { code, .. }) => assert_eq!(code, ErrorCode::Protocol.as_u8()),
        other => panic!("expected a protocol Error frame, got {other:?}"),
    }
    let stats = server.shutdown();
    assert!(stats.malformed >= 2, "stats: {stats:?}");
}

#[test]
fn malformed_frames_and_drops_fail_only_their_own_connection() {
    let service = start_service();
    let server = WireServer::start(service.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr().to_string();
    let engine = Engine::builder().model(MODELS[0]).build().expect("engine");
    let input: Vec<f32> = {
        let mut rng = SplitMix64::new(5);
        (0..engine.input_len()).map(|_| rng.next_sym()).collect()
    };
    let want = engine.infer(&input).expect("reference");

    // A healthy connection, held open across both failure injections.
    let mut healthy = WireClient::connect(&addr).expect("connect healthy");

    // Connection 1: valid handshake + infer, then a garbage frame.
    {
        let mut victim = WireClient::connect(&addr).expect("connect victim");
        assert_eq!(victim.infer(MODELS[0], &input).expect("pre-garbage infer"), want);
        let mut raw = TcpStream::connect(&addr).expect("raw"); // separate garbage conn
        raw.write_all(&[7, 0, 0, 0, 42, 0, 0, 0, 0, 0, 0])
            .expect("garbage bytes");
        let mut reply = Vec::new();
        let _ = raw.read_to_end(&mut reply);
        // The victim connection itself still works fine.
        assert_eq!(victim.infer(MODELS[0], &input).expect("post-garbage infer"), want);
        victim.goodbye().expect("clean teardown");
    }

    // Connection 2: submit then vanish mid-flight (no Goodbye).
    {
        let mut dropper = WireClient::connect(&addr).expect("connect dropper");
        dropper
            .send(99, MODELS[0], input.clone().into())
            .expect("send then drop");
        // dropper's streams close here without reading the response.
    }

    // The healthy connection never noticed either failure.
    for _ in 0..3 {
        assert_eq!(healthy.infer(MODELS[0], &input).expect("healthy infer"), want);
    }
    let table = healthy.metrics_table().expect("metrics over the wire");
    assert!(table.contains(MODELS[0]), "{table}");
    assert!(table.contains("rej"), "{table}");
    healthy.goodbye().expect("clean teardown");

    // Give the server a beat to retire the dropped connection.
    for _ in 0..100 {
        if server.stats().active == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let stats = server.shutdown();
    assert!(stats.malformed >= 1, "stats: {stats:?}");
    let metrics = Arc::try_unwrap(service).ok().expect("last Arc").shutdown();
    // Every admitted request completed — including the dropped
    // connection's (the service finishes what it admits; only the
    // delivery is lost).
    assert_eq!(metrics.total_failed(), 0);
    assert_eq!(metrics.total_completed(), 6);
}

#[test]
fn loadgen_reports_backpressure_and_pipelines() {
    let service = start_service();
    let server = WireServer::start(service.clone(), "127.0.0.1:0").expect("bind");
    let report = run_loadgen(&LoadGenConfig {
        addr: server.local_addr().to_string(),
        connections: 4,
        in_flight: 8,
        requests: 32,
        models: MODELS.iter().map(|m| m.to_string()).collect(),
        seed: 11,
        retry: RetryPolicy::default(),
        deadline_ms: None,
        chaos: None,
        video: None,
        video_delta: 0.0,
    })
    .expect("loadgen");
    assert_eq!(report.sent, 32);
    assert_eq!(report.ok, 32);
    assert_eq!(report.failed, 0);
    assert_eq!(report.rejected_backpressure, 0);
    assert_eq!(report.transport_errors, 0);
    assert!(report.p99_ms >= report.p50_ms);
    assert_eq!(report.lost, 0);
    assert_eq!(report.retried, 0);
    let stats = server.shutdown();
    assert!(stats.max_in_flight >= 1);
    assert_eq!(stats.infer_rx, 32);
    Arc::try_unwrap(service).ok().expect("last Arc").shutdown();
}

#[test]
fn deadlines_expire_on_the_wire_as_code_9() {
    // One worker + a chaos plan that makes every executed batch sleep
    // 400 ms: request 1 (generous deadline) hogs the worker while
    // requests 2 and 3 (150 ms budgets) expire in the queue and must
    // come back as DeadlineExceeded — shed before execution, so the
    // whole test takes ~one slow pass, not three.
    let plan = Arc::new(FaultPlan::new(7).rule(FaultKind::SlowModel { ms: 400 }, Trigger::Always));
    let service = Arc::new(
        InferenceService::builder()
            .model_spec(MODELS[0])
            .workers(1)
            .queue_depth(8)
            .faults(plan.clone())
            .build()
            .expect("service build"),
    );
    let server = WireServer::start(service.clone(), "127.0.0.1:0").expect("bind");
    let mut client = WireClient::connect(&server.local_addr().to_string()).expect("connect");
    let len = client.input_len(MODELS[0]).expect("model advertised");
    let input: Arc<[f32]> = vec![0.25f32; len].into();
    client
        .send_with(1, MODELS[0], input.clone(), 30_000, 0)
        .expect("send 1");
    client
        .send_with(2, MODELS[0], input.clone(), 150, 0)
        .expect("send 2");
    client
        .send_with(3, MODELS[0], input, 150, 0)
        .expect("send 3");
    let mut ok = Vec::new();
    let mut expired = Vec::new();
    for _ in 0..3 {
        match client.recv().expect("response") {
            Frame::Result { id, .. } => ok.push(id),
            Frame::Error { id, code, message } => {
                assert_eq!(code, ErrorCode::DeadlineExceeded.as_u8(), "{message}");
                assert!(message.contains("deadline"), "{message}");
                expired.push(id);
            }
            other => panic!("unexpected frame {other:?}"),
        }
    }
    expired.sort_unstable();
    assert_eq!(ok, vec![1]);
    assert_eq!(expired, vec![2, 3]);
    client.goodbye().expect("teardown");
    assert!(plan.counters().slow_models >= 1, "{}", plan.counters());
    server.shutdown();
    let metrics = Arc::try_unwrap(service).ok().expect("last Arc").shutdown();
    assert_eq!(metrics.total_deadline_exceeded(), 2);
}

#[test]
fn retryable_rejections_are_retried_until_resolved() {
    // queue_depth 1 + Reject admission + a 50 ms slow-model plan: a
    // pipelined burst mostly bounces off the full queue with QueueFull
    // (retryable, code 3). With retries enabled every request must
    // still resolve — ok or rejected after exhausting its budget —
    // and the ledger reconciles: sent == ok + rejected + failed, with
    // the server's per-model retry counter agreeing with the client's.
    let plan = Arc::new(FaultPlan::new(3).rule(FaultKind::SlowModel { ms: 50 }, Trigger::Always));
    let service = Arc::new(
        InferenceService::builder()
            .model_spec(MODELS[0])
            .workers(1)
            .queue_depth(1)
            .admission(AdmissionPolicy::Reject)
            .faults(plan)
            .build()
            .expect("service build"),
    );
    let server = WireServer::start(service.clone(), "127.0.0.1:0").expect("bind");
    let report = run_loadgen(&LoadGenConfig {
        addr: server.local_addr().to_string(),
        connections: 1,
        in_flight: 8,
        requests: 16,
        models: vec![MODELS[0].to_string()],
        seed: 5,
        retry: RetryPolicy {
            max_retries: 6,
            base_backoff_ms: 20,
        },
        deadline_ms: None,
        chaos: None,
        video: None,
        video_delta: 0.0,
    })
    .expect("loadgen");
    assert_eq!(report.sent, 16);
    assert_eq!(report.transport_errors, 0);
    assert_eq!(report.failed, 0);
    assert_eq!(report.lost, 0);
    assert_eq!(report.ok + report.rejected_backpressure, 16);
    assert!(report.retried > 0, "a full queue must have forced retries");
    // Server-side attribution: every re-send carried attempt > 0 and
    // was counted on the model's metrics row.
    let mut probe = WireClient::connect(&server.local_addr().to_string()).expect("connect");
    let table = probe.metrics_table().expect("metrics");
    assert!(
        table.contains(&format!("{} retries", report.retried)),
        "client saw {} retries; table:\n{table}",
        report.retried
    );
    probe.goodbye().expect("teardown");
    server.shutdown();
    Arc::try_unwrap(service).ok().expect("last Arc").shutdown();
}

//! Registry-wide functional-vs-mesh bit-exactness: every entry of the
//! built-in `NetworkRegistry` — including YOLOv3, whose FPN laterals
//! exercise the 2× nearest-upsample + halo re-exchange path — must
//! produce bit-identical outputs on the single-chip functional backend
//! and the 2×2 systolic mesh, from the same spec + seed. Small
//! resolutions keep the sweep fast; shapes are chosen so every tensor
//! (down to the deepest /32 grid) divides over the mesh.

use std::collections::HashMap;

use hyperdrive::engine::{Engine, Precision};
use hyperdrive::model::NetworkRegistry;
use hyperdrive::util::SplitMix64;

fn random_input(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..len).map(|_| rng.next_sym()).collect()
}

#[test]
fn every_registry_entry_is_bit_exact_on_both_backends() {
    // Per-entry sweep spec: smallest resolution whose tensors all
    // divide over 2×2 chips. The upsampling network (yolov3) and the
    // AOT twin run the silicon's FP16 datapath to also pin the rounding
    // order; the rest use f32 (bit-exactness is equally strict, the
    // sweep just runs faster without per-accumulate rounding).
    let sweep: HashMap<&str, (&str, Precision)> = [
        ("resnet18", ("resnet18@64x64", Precision::F32)),
        ("resnet34", ("resnet34@64x64", Precision::F32)),
        ("resnet50", ("resnet50@64x64", Precision::F32)),
        ("resnet152", ("resnet152@64x64", Precision::F32)),
        ("shufflenet", ("shufflenet@64x64", Precision::F32)),
        ("yolov3", ("yolov3@64x64", Precision::F16)),
        ("tinyyolo", ("tinyyolo@64x64", Precision::F32)),
        ("hypernet20", ("hypernet20", Precision::F16)),
    ]
    .into_iter()
    .collect();

    for name in NetworkRegistry::builtin().names() {
        let (spec, prec) = *sweep
            .get(name)
            .unwrap_or_else(|| panic!("registry entry `{name}` has no sweep spec — add one"));
        let functional = Engine::builder()
            .model(spec)
            .seed(0x5eed)
            .precision(prec)
            .threads(2)
            .build()
            .unwrap_or_else(|e| panic!("{spec} functional build: {e}"));
        let mesh = Engine::builder()
            .model(spec)
            .seed(0x5eed)
            .mesh(2, 2)
            .precision(prec)
            .build()
            .unwrap_or_else(|e| panic!("{spec} mesh build: {e}"));
        let input = random_input(functional.input_len(), 42);
        let a = functional
            .infer(&input)
            .unwrap_or_else(|e| panic!("{spec} functional infer: {e}"));
        let b = mesh
            .infer(&input)
            .unwrap_or_else(|e| panic!("{spec} mesh infer: {e}"));
        assert_eq!(a, b, "{spec} ({prec:?}) diverged across backends");
        assert!(
            a.iter().all(|v| v.is_finite()),
            "{spec} produced non-finite outputs"
        );
        let stats = mesh.mesh_stats().expect("mesh stats recorded");
        assert!(stats.access.fmm_writes > 0, "{spec}: no kernel traffic counted");
    }
}

#[test]
fn yolov3_traces_match_layer_by_layer_including_upsample() {
    // The per-step trace compares every intermediate FM, so a
    // divergence pinpoints the first bad layer; the upsampled laterals
    // (h0lat/h1lat) report their doubled shape on both backends.
    let functional = Engine::builder()
        .model("yolov3@64x64")
        .seed(7)
        .precision(Precision::F16)
        .threads(2)
        .build()
        .unwrap();
    let mesh = Engine::builder()
        .model("yolov3@64x64")
        .seed(7)
        .mesh(2, 2)
        .precision(Precision::F16)
        .build()
        .unwrap();
    let input = random_input(functional.input_len(), 9);
    let mut func_fms: Vec<(String, (usize, usize, usize), Vec<f32>)> = Vec::new();
    functional
        .infer_traced(&input, &mut |t| {
            func_fms.push((t.layer.to_string(), t.shape, t.output.to_vec()));
        })
        .unwrap();
    let (_, (_, lat_h, lat_w), _) = func_fms
        .iter()
        .find(|(n, _, _)| n == "h0lat")
        .expect("h0lat traced");
    // 64×64 image → scale-0 grid 2×2, upsampled lateral 4×4.
    assert_eq!((*lat_h, *lat_w), (4, 4), "h0lat must be stored upsampled");
    let mut steps = 0usize;
    mesh.infer_traced(&input, &mut |t| {
        let (name, shape, data) = &func_fms[t.step];
        assert_eq!(t.layer, name.as_str());
        assert_eq!(t.shape, *shape, "step {} ({name}) shape", t.step);
        assert_eq!(t.output, &data[..], "step {} ({name}) diverged", t.step);
        steps += 1;
    })
    .unwrap();
    assert_eq!(steps, func_fms.len());
}

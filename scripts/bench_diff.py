#!/usr/bin/env python3
"""Gate a fresh BENCH_hotpath.json against the committed baseline.

Two independent gates, both enforced by the CI `bench-smoke` job:

1. **Kernel-vs-reference speedup** (machine-independent, every run):
   `benches/hotpath.rs` times the optimized datapath kernel *and* the
   preserved pre-optimization kernel (`testkit::reference_run_tile`,
   the "(… reference kernel)" entries) in the same process on the same
   machine.  The optimized conv entry must be >= 2.5x faster at F32 and
   >= 1.3x faster at F16 (min-time ratio — min is the noise-robust
   statistic for short runs).  The F32 floor was raised from 2.0x when
   the kernel moved to once-per-layer `PackedLayerWeights` sign planes
   and 8-wide pixel blocks; F16 stays at 1.3x because its serial
   `round_f16` chain dominates either way.

2. **Absolute regression vs the committed baseline**: every entry named
   in the baseline must still exist, and — when baseline and current
   run report the same host fingerprint — its mean time may not regress
   by more than --max-regress (default 20%).  A baseline marked
   `"bootstrap": true` (no toolchain was available to capture absolute
   numbers when it was committed) skips the absolute comparison and
   prints the refresh command instead.

3. **Micro-batch weight-traffic amortization** (`--serve PATH`,
   machine-independent): `benches/serve.rs` emits a `batch_entries`
   curve sweeping B ∈ {1, 2, 4, 8} per model.  The analytic
   weight-stream counters must show each weight block streamed once per
   batch: `stream_words <= stream_words_seq * (1/B + eps)`.  These are
   exact counters, not timings, so the gate holds on any host.

5. **Streaming-video savings curve** (`--serve PATH`): the
   `video_entries` sweep (delta 0 → 1) must be bit-exact vs full
   recompute at every point, satisfy the analytic identity
   saved-MAC ratio == 1 − MAC-weighted dirty fraction, decrease
   monotonically with delta, and hit its endpoints (static stream
   saves ~all MACs, fully-changing stream ~none).

4. **Worker/transport sweep shape + p99 blow-up** (`--serve PATH`):
   the `sweep` section must cover both transports (in-process and
   loopback TCP) over the same ascending worker counts, every point
   must account for all its requests (ok + failed + rejected ==
   requests, ok > 0), and scaling workers up must not blow up tail
   latency: p99 at the highest worker count may not exceed
   P99_BLOWUP_FACTOR × p99 at 1 worker once it is past the
   P99_ABS_FLOOR_MS noise floor.  Throughput scaling is printed as an
   advisory (shared CI runners are too noisy to gate req/s).

usage: bench_diff.py BASELINE CURRENT [--max-regress 0.20] [--serve BENCH_serve.json]
"""

import argparse
import json
import sys

REF_SUFFIX = ", reference kernel)"
# (substring of the entry name, required min-time speedup vs reference;
# None = advisory, printed but never failing).  The tiny (CI smoke) spec
# amortizes the per-call staging over ~25x less work and times far fewer
# iterations on a shared runner, so its F32 gate is looser and its F16
# gate — where the win is smallest (round_f16 cost is identical in both
# kernels) — is advisory; the full-size bench is where the 2.5x
# acceptance target is enforced.
SPEEDUP_GATES = [("(F32, 1 thread", 2.5), ("(F16, 1 thread", 1.3)]
TINY_SPEEDUP_GATES = [("(F32, 1 thread", 1.5), ("(F16, 1 thread", None)]

# Slack on the 1/B weight-traffic ratio.  The counters are analytic
# (words, not seconds) so the only legitimate deviation is a layer whose
# stream cost is not perfectly divisible across the batch; 2% covers it.
BATCH_RATIO_EPS = 0.02
BATCH_SWEEP = [1, 2, 4, 8]

# Streaming-video gate: the delta points the bench must sweep, the
# slack on the analytic saved-MACs identity (saved ratio == 1 − the
# MAC-weighted dirty fraction — exact counters, so the only tolerance
# needed is float aggregation noise), and the endpoint expectations
# (a static stream saves ~everything, a fully-changing one ~nothing).
VIDEO_SWEEP = [0.0, 0.05, 0.25, 1.0]
VIDEO_IDENTITY_EPS = 1e-3
VIDEO_STATIC_MIN_SAVED = 0.999
VIDEO_FULL_MAX_SAVED = 1e-3

# The worker sweep's tail-latency gate: p99 at the top worker count may
# not exceed this multiple of p99 at 1 worker — unless both sit under
# the absolute floor, where scheduler jitter on a shared runner
# dominates real signal.
P99_BLOWUP_FACTOR = 3.0
P99_ABS_FLOOR_MS = 50.0
SWEEP_TRANSPORTS = ["in-process", "tcp"]


def load(path):
    with open(path) as f:
        d = json.load(f)
    assert d.get("bench") == "hotpath", f"{path}: not a hotpath bench file"
    assert isinstance(d.get("entries"), list), f"{path}: no entries list"
    return d


def speedup_gate(cur, failures):
    by_name = {e["name"]: e for e in cur["entries"]}
    gates = TINY_SPEEDUP_GATES if cur.get("tiny") else SPEEDUP_GATES
    if cur.get("tiny"):
        print("tiny run: using relaxed smoke gates "
              f"{[(p, g) for p, g in gates]}")
    pairs = 0
    for e in cur["entries"]:
        if not e["name"].endswith(REF_SUFFIX):
            continue
        fast_name = e["name"].replace(REF_SUFFIX, ")")
        fast = by_name.get(fast_name)
        if fast is None:
            failures.append(
                f"reference entry `{e['name']}` has no optimized twin `{fast_name}`"
            )
            continue
        pairs += 1
        speedup = e["min_s"] / fast["min_s"]
        gate = next((g for pat, g in gates if pat in e["name"]), 1.0)
        if gate is None:
            print(
                f"advisory: `{fast_name}`: {speedup:.2f}x vs pre-optimization "
                "reference (not gated in this mode)"
            )
            continue
        line = (
            f"`{fast_name}`: {speedup:.2f}x vs pre-optimization reference "
            f"(gate >= {gate:.1f}x)"
        )
        if speedup < gate:
            failures.append(line)
        else:
            print(f"ok: {line}")
    if pairs == 0:
        failures.append(
            "no '(… reference kernel)' entries found — the speedup gate has "
            "nothing to measure (bench renamed?)"
        )


def baseline_gate(base, cur, max_regress, failures):
    if base.get("bootstrap"):
        print(
            "baseline is a bootstrap placeholder (no absolute numbers); "
            "refresh with:\n  cd rust && HOTPATH_TINY=1 cargo bench --bench hotpath "
            "&& cp BENCH_hotpath.json benches/BENCH_hotpath.baseline.json\n"
            "(use HOTPATH_TINY=1 so the entry names match what the CI "
            "bench-smoke job produces; drop it for a local full-size baseline)"
        )
        return
    if bool(base.get("tiny")) != bool(cur.get("tiny")):
        # Tiny and full runs use different conv shapes, so their entry
        # names can never line up — comparing them would report every
        # baseline entry as missing and brick the gate.
        print(
            f"baseline mode (tiny={base.get('tiny')}) != current mode "
            f"(tiny={cur.get('tiny')}): skipping the baseline diff"
        )
        return
    by_name = {e["name"]: e for e in cur["entries"]}
    same_host = base.get("host") is not None and base.get("host") == cur.get("host")
    if not same_host:
        print(
            f"host mismatch (baseline `{base.get('host')}` vs current "
            f"`{cur.get('host')}`): checking entry coverage only, not absolute times"
        )
    for be in base["entries"]:
        ce = by_name.get(be["name"])
        if ce is None:
            failures.append(f"baseline entry `{be['name']}` disappeared from the bench")
            continue
        if not same_host:
            continue
        limit = be["mean_s"] * (1.0 + max_regress)
        if ce["mean_s"] > limit:
            failures.append(
                f"`{be['name']}` regressed: mean {ce['mean_s']:.6f}s vs baseline "
                f"{be['mean_s']:.6f}s (>{max_regress:.0%})"
            )
        else:
            print(
                f"ok: `{be['name']}` mean {ce['mean_s']:.6f}s within "
                f"{max_regress:.0%} of baseline {be['mean_s']:.6f}s"
            )


def serve_gates(path, failures):
    """Load BENCH_serve.json once and run the batch + sweep gates."""
    with open(path) as f:
        d = json.load(f)
    if d.get("bench") != "serve":
        failures.append(f"{path}: not a serve bench file")
        return
    serve_batch_gate(path, d, failures)
    serve_sweep_gate(path, d, failures)
    serve_video_gate(path, d, failures)


def serve_batch_gate(path, d, failures):
    entries = d.get("batch_entries")
    if not isinstance(entries, list) or not entries:
        failures.append(
            f"{path}: no batch_entries — the micro-batch curve has nothing "
            "to gate (bench section renamed?)"
        )
        return
    by_model = {}
    for e in entries:
        by_model.setdefault(e["model"], []).append(e)
    for model, rows in sorted(by_model.items()):
        got = sorted(r["batch"] for r in rows)
        if got != BATCH_SWEEP:
            failures.append(
                f"{path}: model `{model}` batch sweep is {got}, "
                f"expected {BATCH_SWEEP}"
            )
        for r in rows:
            b, sw, seq = r["batch"], r["stream_words"], r["stream_words_seq"]
            if sw <= 0 or seq <= 0:
                failures.append(
                    f"`{model}` B={b}: stream counters not wired "
                    f"(stream_words={sw}, stream_words_seq={seq})"
                )
                continue
            ratio = sw / seq
            limit = 1.0 / b + BATCH_RATIO_EPS
            line = (
                f"`{model}` B={b}: weight-traffic ratio {ratio:.4f} "
                f"(gate <= 1/{b} + {BATCH_RATIO_EPS} = {limit:.4f})"
            )
            if ratio > limit:
                failures.append(line)
            else:
                print(f"ok: {line}")


def serve_sweep_gate(path, d, failures):
    sweep = d.get("sweep")
    if not isinstance(sweep, dict) or not isinstance(sweep.get("entries"), list) \
            or not sweep["entries"]:
        failures.append(
            f"{path}: no sweep section — the worker/transport sweep has "
            "nothing to gate (bench section renamed?)"
        )
        return
    rows = sweep["entries"]
    by_transport = {}
    for e in rows:
        by_transport.setdefault(e.get("transport"), []).append(e)
    worker_sets = {}
    for t in SWEEP_TRANSPORTS:
        if t not in by_transport:
            failures.append(f"{path}: sweep has no `{t}` entries")
            continue
        workers = [e["workers"] for e in by_transport[t]]
        if workers != sorted(set(workers)):
            failures.append(
                f"{path}: `{t}` sweep worker counts not strictly ascending: {workers}"
            )
        worker_sets[t] = workers
    if len(set(map(tuple, worker_sets.values()))) > 1:
        failures.append(
            f"{path}: transports sweep different worker sets: {worker_sets}"
        )
    for e in rows:
        total = e["ok"] + e["failed"] + e["rejected"]
        if e["ok"] <= 0 or total != e["requests"]:
            failures.append(
                f"sweep {e.get('transport')}@{e.get('workers')}w: requests don't "
                f"add up (ok {e['ok']} + failed {e['failed']} + rejected "
                f"{e['rejected']} != {e['requests']})"
            )
    for t, entries in sorted(by_transport.items()):
        if len(entries) < 2:
            continue
        lo, hi = entries[0], entries[-1]
        if lo["req_per_s"] > 0:
            print(
                f"advisory: `{t}` throughput {lo['req_per_s']:.1f} req/s @ "
                f"{lo['workers']}w → {hi['req_per_s']:.1f} req/s @ "
                f"{hi['workers']}w ({hi['req_per_s'] / lo['req_per_s']:.2f}x)"
            )
        line = (
            f"`{t}` p99 {lo['p99_ms']:.2f} ms @ {lo['workers']}w → "
            f"{hi['p99_ms']:.2f} ms @ {hi['workers']}w "
            f"(gate <= {P99_BLOWUP_FACTOR}x past the {P99_ABS_FLOOR_MS} ms floor)"
        )
        blown = (
            hi["p99_ms"] > P99_ABS_FLOOR_MS
            and hi["p99_ms"] > P99_BLOWUP_FACTOR * max(lo["p99_ms"], 1e-9)
        )
        if blown:
            failures.append(f"sweep {line}")
        else:
            print(f"ok: {line}")


def serve_video_gate(path, d, failures):
    """Gate the streaming-video curve (video_entries).

    Four machine-independent checks per model: (1) every point is
    bit-exact vs full recompute, (2) the saved-MAC ratio equals
    1 − the MAC-weighted dirty fraction (clean tiles are spliced,
    dirty ones recomputed — there is no third bucket), (3) savings are
    monotone non-increasing as the frame delta grows, and (4) the
    endpoints behave: a static stream saves ~all MACs, a
    fully-changing stream ~none.
    """
    entries = d.get("video_entries")
    if not isinstance(entries, list) or not entries:
        failures.append(
            f"{path}: no video_entries — the streaming-video curve has "
            "nothing to gate (bench section renamed?)"
        )
        return
    by_model = {}
    for e in entries:
        by_model.setdefault(e["model"], []).append(e)
    for model, rows in sorted(by_model.items()):
        deltas = [r["delta"] for r in rows]
        if deltas != sorted(deltas) or not (
            deltas[0] == 0.0 and deltas[-1] == 1.0 and len(deltas) >= len(VIDEO_SWEEP)
        ):
            failures.append(
                f"{path}: `{model}` video sweep is {deltas}, expected the "
                f"ascending endpoints of {VIDEO_SWEEP}"
            )
        for r in rows:
            tag = f"`{model}` delta={r['delta']:.2f}"
            if not r.get("bit_exact"):
                failures.append(f"{tag}: video output diverged from full recompute")
            ident = 1.0 - r["mac_dirty_fraction"]
            if abs(r["saved_mac_ratio"] - ident) > VIDEO_IDENTITY_EPS:
                failures.append(
                    f"{tag}: saved-MAC ratio {r['saved_mac_ratio']:.6f} != "
                    f"1 - dirty fraction {ident:.6f} (eps {VIDEO_IDENTITY_EPS})"
                )
            else:
                print(
                    f"ok: {tag} saved {r['saved_mac_ratio']:.4f} == "
                    f"1 - dirty {r['mac_dirty_fraction']:.4f}"
                )
        saved = [r["saved_mac_ratio"] for r in rows]
        if any(a < b - VIDEO_IDENTITY_EPS for a, b in zip(saved, saved[1:])):
            failures.append(
                f"{path}: `{model}` saved-MAC ratio not monotone over delta: {saved}"
            )
        if rows[0]["delta"] == 0.0 and saved[0] < VIDEO_STATIC_MIN_SAVED:
            failures.append(
                f"`{model}` delta=0: static stream saved only {saved[0]:.4f} "
                f"of MACs (gate >= {VIDEO_STATIC_MIN_SAVED})"
            )
        if rows[-1]["delta"] == 1.0 and saved[-1] > VIDEO_FULL_MAX_SAVED:
            failures.append(
                f"`{model}` delta=1: fully-changing stream still saved "
                f"{saved[-1]:.4f} of MACs (gate <= {VIDEO_FULL_MAX_SAVED})"
            )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--max-regress", type=float, default=0.20)
    ap.add_argument(
        "--serve",
        metavar="PATH",
        help="also gate the batch_entries curve and worker/transport sweep "
        "of a BENCH_serve.json",
    )
    args = ap.parse_args()
    base, cur = load(args.baseline), load(args.current)

    failures = []
    speedup_gate(cur, failures)
    baseline_gate(base, cur, args.max_regress, failures)
    if args.serve:
        serve_gates(args.serve, failures)

    if failures:
        print("\nFAIL:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print("bench diff: all gates passed")


if __name__ == "__main__":
    main()

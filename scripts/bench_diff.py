#!/usr/bin/env python3
"""Gate a fresh BENCH_hotpath.json against the committed baseline.

Two independent gates, both enforced by the CI `bench-smoke` job:

1. **Kernel-vs-reference speedup** (machine-independent, every run):
   `benches/hotpath.rs` times the optimized datapath kernel *and* the
   preserved pre-optimization kernel (`testkit::reference_run_tile`,
   the "(… reference kernel)" entries) in the same process on the same
   machine.  The optimized conv entry must be >= 2.0x faster at F32 and
   >= 1.3x faster at F16 (min-time ratio — min is the noise-robust
   statistic for short runs).

2. **Absolute regression vs the committed baseline**: every entry named
   in the baseline must still exist, and — when baseline and current
   run report the same host fingerprint — its mean time may not regress
   by more than --max-regress (default 20%).  A baseline marked
   `"bootstrap": true` (no toolchain was available to capture absolute
   numbers when it was committed) skips the absolute comparison and
   prints the refresh command instead.

3. **Micro-batch weight-traffic amortization** (`--serve PATH`,
   machine-independent): `benches/serve.rs` emits a `batch_entries`
   curve sweeping B ∈ {1, 2, 4, 8} per model.  The analytic
   weight-stream counters must show each weight block streamed once per
   batch: `stream_words <= stream_words_seq * (1/B + eps)`.  These are
   exact counters, not timings, so the gate holds on any host.

usage: bench_diff.py BASELINE CURRENT [--max-regress 0.20] [--serve BENCH_serve.json]
"""

import argparse
import json
import sys

REF_SUFFIX = ", reference kernel)"
# (substring of the entry name, required min-time speedup vs reference;
# None = advisory, printed but never failing).  The tiny (CI smoke) spec
# amortizes the per-call staging over ~25x less work and times far fewer
# iterations on a shared runner, so its F32 gate is looser and its F16
# gate — where the win is smallest (round_f16 cost is identical in both
# kernels) — is advisory; the full-size bench is where the 2x
# acceptance target is enforced.
SPEEDUP_GATES = [("(F32, 1 thread", 2.0), ("(F16, 1 thread", 1.3)]
TINY_SPEEDUP_GATES = [("(F32, 1 thread", 1.5), ("(F16, 1 thread", None)]

# Slack on the 1/B weight-traffic ratio.  The counters are analytic
# (words, not seconds) so the only legitimate deviation is a layer whose
# stream cost is not perfectly divisible across the batch; 2% covers it.
BATCH_RATIO_EPS = 0.02
BATCH_SWEEP = [1, 2, 4, 8]


def load(path):
    with open(path) as f:
        d = json.load(f)
    assert d.get("bench") == "hotpath", f"{path}: not a hotpath bench file"
    assert isinstance(d.get("entries"), list), f"{path}: no entries list"
    return d


def speedup_gate(cur, failures):
    by_name = {e["name"]: e for e in cur["entries"]}
    gates = TINY_SPEEDUP_GATES if cur.get("tiny") else SPEEDUP_GATES
    if cur.get("tiny"):
        print("tiny run: using relaxed smoke gates "
              f"{[(p, g) for p, g in gates]}")
    pairs = 0
    for e in cur["entries"]:
        if not e["name"].endswith(REF_SUFFIX):
            continue
        fast_name = e["name"].replace(REF_SUFFIX, ")")
        fast = by_name.get(fast_name)
        if fast is None:
            failures.append(
                f"reference entry `{e['name']}` has no optimized twin `{fast_name}`"
            )
            continue
        pairs += 1
        speedup = e["min_s"] / fast["min_s"]
        gate = next((g for pat, g in gates if pat in e["name"]), 1.0)
        if gate is None:
            print(
                f"advisory: `{fast_name}`: {speedup:.2f}x vs pre-optimization "
                "reference (not gated in this mode)"
            )
            continue
        line = (
            f"`{fast_name}`: {speedup:.2f}x vs pre-optimization reference "
            f"(gate >= {gate:.1f}x)"
        )
        if speedup < gate:
            failures.append(line)
        else:
            print(f"ok: {line}")
    if pairs == 0:
        failures.append(
            "no '(… reference kernel)' entries found — the speedup gate has "
            "nothing to measure (bench renamed?)"
        )


def baseline_gate(base, cur, max_regress, failures):
    if base.get("bootstrap"):
        print(
            "baseline is a bootstrap placeholder (no absolute numbers); "
            "refresh with:\n  cd rust && HOTPATH_TINY=1 cargo bench --bench hotpath "
            "&& cp BENCH_hotpath.json benches/BENCH_hotpath.baseline.json\n"
            "(use HOTPATH_TINY=1 so the entry names match what the CI "
            "bench-smoke job produces; drop it for a local full-size baseline)"
        )
        return
    if bool(base.get("tiny")) != bool(cur.get("tiny")):
        # Tiny and full runs use different conv shapes, so their entry
        # names can never line up — comparing them would report every
        # baseline entry as missing and brick the gate.
        print(
            f"baseline mode (tiny={base.get('tiny')}) != current mode "
            f"(tiny={cur.get('tiny')}): skipping the baseline diff"
        )
        return
    by_name = {e["name"]: e for e in cur["entries"]}
    same_host = base.get("host") is not None and base.get("host") == cur.get("host")
    if not same_host:
        print(
            f"host mismatch (baseline `{base.get('host')}` vs current "
            f"`{cur.get('host')}`): checking entry coverage only, not absolute times"
        )
    for be in base["entries"]:
        ce = by_name.get(be["name"])
        if ce is None:
            failures.append(f"baseline entry `{be['name']}` disappeared from the bench")
            continue
        if not same_host:
            continue
        limit = be["mean_s"] * (1.0 + max_regress)
        if ce["mean_s"] > limit:
            failures.append(
                f"`{be['name']}` regressed: mean {ce['mean_s']:.6f}s vs baseline "
                f"{be['mean_s']:.6f}s (>{max_regress:.0%})"
            )
        else:
            print(
                f"ok: `{be['name']}` mean {ce['mean_s']:.6f}s within "
                f"{max_regress:.0%} of baseline {be['mean_s']:.6f}s"
            )


def serve_batch_gate(path, failures):
    with open(path) as f:
        d = json.load(f)
    if d.get("bench") != "serve":
        failures.append(f"{path}: not a serve bench file")
        return
    entries = d.get("batch_entries")
    if not isinstance(entries, list) or not entries:
        failures.append(
            f"{path}: no batch_entries — the micro-batch curve has nothing "
            "to gate (bench section renamed?)"
        )
        return
    by_model = {}
    for e in entries:
        by_model.setdefault(e["model"], []).append(e)
    for model, rows in sorted(by_model.items()):
        got = sorted(r["batch"] for r in rows)
        if got != BATCH_SWEEP:
            failures.append(
                f"{path}: model `{model}` batch sweep is {got}, "
                f"expected {BATCH_SWEEP}"
            )
        for r in rows:
            b, sw, seq = r["batch"], r["stream_words"], r["stream_words_seq"]
            if sw <= 0 or seq <= 0:
                failures.append(
                    f"`{model}` B={b}: stream counters not wired "
                    f"(stream_words={sw}, stream_words_seq={seq})"
                )
                continue
            ratio = sw / seq
            limit = 1.0 / b + BATCH_RATIO_EPS
            line = (
                f"`{model}` B={b}: weight-traffic ratio {ratio:.4f} "
                f"(gate <= 1/{b} + {BATCH_RATIO_EPS} = {limit:.4f})"
            )
            if ratio > limit:
                failures.append(line)
            else:
                print(f"ok: {line}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--max-regress", type=float, default=0.20)
    ap.add_argument(
        "--serve",
        metavar="PATH",
        help="also gate the batch_entries curve of a BENCH_serve.json",
    )
    args = ap.parse_args()
    base, cur = load(args.baseline), load(args.current)

    failures = []
    speedup_gate(cur, failures)
    baseline_gate(base, cur, args.max_regress, failures)
    if args.serve:
        serve_batch_gate(args.serve, failures)

    if failures:
        print("\nFAIL:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print("bench diff: all gates passed")


if __name__ == "__main__":
    main()
